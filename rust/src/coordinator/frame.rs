//! Length-prefixed binary framing for the serving front.
//!
//! The text protocol of [`crate::coordinator::server`] is one line per
//! request and per reply — easy to drive from `nc`, but every request
//! costs a linear newline scan and a UTF-8 pass, and a reply cannot be
//! correlated to its request, so a connection can only be used
//! synchronously.  The binary framing fixes both: a fixed 20-byte
//! header carries the opcode, the tenant, a client-chosen request id
//! (echoed on the reply, so one connection can multiplex many in-flight
//! requests), and the payload length, followed by the payload bytes.
//!
//! Wire layout (all multi-byte fields little-endian):
//!
//! ```text
//! offset  size  field
//! 0       4     magic  C6 47 52 41          ("\xC6GRA")
//! 4       1     version (currently 1)
//! 5       1     opcode ([`Opcode`])
//! 6       2     tenant (u16; requests only, zero in replies)
//! 8       8     req_id (u64; echoed verbatim on the reply)
//! 16      4     payload length (u32, ≤ [`MAX_PAYLOAD`])
//! 20      len   payload
//! ```
//!
//! The first magic byte is `0xC6` — not valid ASCII and not the first
//! byte of any text-protocol verb — so a server can negotiate the
//! protocol from the first byte a connection sends (see
//! [`crate::config::WireProtocolKind`]).
//!
//! Request payloads reuse the text protocol's argument syntax (a SUBMIT
//! payload is `<app> [class] [deadline_ms]`; the tenant rides in the
//! header).  Reply payloads are the *exact* text-protocol reply bytes,
//! including embedded newlines for multi-line `STATS` surfaces — which
//! is what lets the conformance suite assert byte-identical behavior
//! across both protocols.
//!
//! [`decode`] is incremental and zero-copy: it borrows the payload
//! straight out of the caller's receive buffer and reports exactly how
//! many bytes one frame consumed, so a reactor can feed it partial
//! reads and coalesced multi-frame buffers alike.  Decoding is a pure
//! function of the buffer prefix, which makes the byte-at-a-time and
//! whole-buffer decode paths trivially equivalent (property-tested in
//! `tests/prop_frame.rs`).

use std::fmt;

/// Frame magic: `0xC6` then `"GRA"`.
pub const MAGIC: [u8; 4] = [0xC6, 0x47, 0x52, 0x41];

/// Current framing version.
pub const VERSION: u8 = 1;

/// Fixed header length in bytes.
pub const HEADER_LEN: usize = 20;

/// Maximum payload length a peer may send; larger length prefixes are
/// rejected before any buffering ([`FrameError::Oversized`]).
pub const MAX_PAYLOAD: usize = 64 * 1024;

/// Frame opcodes.  Requests occupy the low range, replies the high bit;
/// a reply's opcode mirrors the first token of the text-protocol reply
/// line it carries ([`Opcode::for_reply_line`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Opcode {
    /// `SUBMIT`: payload `<app> [class] [deadline_ms]`, tenant in header.
    Submit,
    /// `STATS`: payload is the subcommand bytes (empty for aggregate).
    Stats,
    /// `DEFRAG`: empty payload.
    Defrag,
    /// `QUIT`: close this connection after the reply.
    Quit,
    /// `SHUTDOWN`: graceful server shutdown.
    Shutdown,
    /// `EXPLAIN`: payload is the decimal request sequence number.
    Explain,
    /// `WATCH`: subscribe this connection to the live journal stream.
    Watch,
    /// `DUMP`: cut a flight-recorder snapshot; empty payload.
    Dump,
    /// Reply carrying an `OK …` line.
    ReplyOk,
    /// Reply carrying a `BUSY …` backpressure line.
    ReplyBusy,
    /// Reply carrying an `ERR …` line.
    ReplyErr,
    /// Reply carrying a (possibly multi-line) `STATS …` payload.
    ReplyStats,
    /// Reply carrying a `DEFRAG …` line.
    ReplyDefrag,
    /// Reply carrying a `BYE …` line.
    ReplyBye,
    /// Reply carrying a (multi-line) `EXPLAIN …` decision chain.
    ReplyExplain,
    /// Reply carrying a `WATCH ok` / `WATCH done …` line.
    ReplyWatch,
    /// Reply carrying a `DUMP …` flight-recorder snapshot.
    ReplyDump,
    /// Unsolicited `EVENT …` line pushed to a watching connection
    /// (req_id zero — events are not replies to any request).
    ReplyEvent,
}

impl Opcode {
    /// Wire encoding of this opcode.
    pub fn as_u8(self) -> u8 {
        match self {
            Opcode::Submit => 0x01,
            Opcode::Stats => 0x02,
            Opcode::Defrag => 0x03,
            Opcode::Quit => 0x04,
            Opcode::Shutdown => 0x05,
            Opcode::Explain => 0x06,
            Opcode::Watch => 0x07,
            Opcode::Dump => 0x08,
            Opcode::ReplyOk => 0x81,
            Opcode::ReplyBusy => 0x82,
            Opcode::ReplyErr => 0x83,
            Opcode::ReplyStats => 0x84,
            Opcode::ReplyDefrag => 0x85,
            Opcode::ReplyBye => 0x86,
            Opcode::ReplyExplain => 0x87,
            Opcode::ReplyWatch => 0x88,
            Opcode::ReplyDump => 0x89,
            Opcode::ReplyEvent => 0x8A,
        }
    }

    /// Decode a wire opcode byte.
    pub fn from_u8(v: u8) -> Option<Opcode> {
        match v {
            0x01 => Some(Opcode::Submit),
            0x02 => Some(Opcode::Stats),
            0x03 => Some(Opcode::Defrag),
            0x04 => Some(Opcode::Quit),
            0x05 => Some(Opcode::Shutdown),
            0x06 => Some(Opcode::Explain),
            0x07 => Some(Opcode::Watch),
            0x08 => Some(Opcode::Dump),
            0x81 => Some(Opcode::ReplyOk),
            0x82 => Some(Opcode::ReplyBusy),
            0x83 => Some(Opcode::ReplyErr),
            0x84 => Some(Opcode::ReplyStats),
            0x85 => Some(Opcode::ReplyDefrag),
            0x86 => Some(Opcode::ReplyBye),
            0x87 => Some(Opcode::ReplyExplain),
            0x88 => Some(Opcode::ReplyWatch),
            0x89 => Some(Opcode::ReplyDump),
            0x8A => Some(Opcode::ReplyEvent),
            _ => None,
        }
    }

    /// Whether this opcode is a client request (as opposed to a reply).
    pub fn is_request(self) -> bool {
        self.as_u8() & 0x80 == 0
    }

    /// Reply opcode for a text-protocol reply line, keyed on its first
    /// token.  Unknown shapes map to [`Opcode::ReplyErr`] — every reply
    /// the server emits starts with one of the known tokens.
    pub fn for_reply_line(line: &str) -> Opcode {
        match line.split_whitespace().next() {
            Some("OK") => Opcode::ReplyOk,
            Some("BUSY") => Opcode::ReplyBusy,
            Some("STATS") => Opcode::ReplyStats,
            Some("DEFRAG") => Opcode::ReplyDefrag,
            Some("BYE") => Opcode::ReplyBye,
            Some("EXPLAIN") => Opcode::ReplyExplain,
            Some("WATCH") => Opcode::ReplyWatch,
            Some("DUMP") => Opcode::ReplyDump,
            Some("EVENT") => Opcode::ReplyEvent,
            _ => Opcode::ReplyErr,
        }
    }
}

/// A decode failure.  Every variant is a protocol violation that the
/// server answers with one `ERR bad frame: …` reply before closing the
/// connection — a malformed peer can never desynchronize the stream.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FrameError {
    /// Byte at `offset` (< 4) does not match [`MAGIC`].
    BadMagic { byte: u8, offset: usize },
    /// Unsupported framing version.
    BadVersion(u8),
    /// Unknown opcode byte.
    BadOpcode(u8),
    /// Length prefix exceeds [`MAX_PAYLOAD`].
    Oversized(u32),
}

impl fmt::Display for FrameError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FrameError::BadMagic { byte, offset } => {
                write!(f, "bad magic byte 0x{byte:02x} at offset {offset}")
            }
            FrameError::BadVersion(v) => write!(f, "unsupported version {v}"),
            FrameError::BadOpcode(v) => write!(f, "unknown opcode 0x{v:02x}"),
            FrameError::Oversized(n) => {
                write!(f, "payload length {n} exceeds {MAX_PAYLOAD}")
            }
        }
    }
}

/// One decoded frame, borrowing its payload from the receive buffer.
#[derive(Debug, PartialEq, Eq)]
pub struct Frame<'a> {
    /// Frame opcode.
    pub opcode: Opcode,
    /// Tenant id (requests; zero in replies).
    pub tenant: u16,
    /// Client-chosen request id, echoed on the reply.
    pub req_id: u64,
    /// Payload bytes (borrowed, zero-copy).
    pub payload: &'a [u8],
}

/// Total encoded size of a frame with a `payload_len`-byte payload.
pub fn encoded_len(payload_len: usize) -> usize {
    HEADER_LEN + payload_len
}

/// Append one encoded frame to `out`.
///
/// Panics (debug assertion) if `payload` exceeds [`MAX_PAYLOAD`] — the
/// server's replies are bounded well below it and clients must chunk.
pub fn encode_into(out: &mut Vec<u8>, opcode: Opcode, tenant: u16, req_id: u64, payload: &[u8]) {
    debug_assert!(payload.len() <= MAX_PAYLOAD, "frame payload too large");
    out.reserve(encoded_len(payload.len()));
    out.extend_from_slice(&MAGIC);
    out.push(VERSION);
    out.push(opcode.as_u8());
    out.extend_from_slice(&tenant.to_le_bytes());
    out.extend_from_slice(&req_id.to_le_bytes());
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(payload);
}

/// Encode one frame into a fresh buffer.
pub fn encode(opcode: Opcode, tenant: u16, req_id: u64, payload: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(encoded_len(payload.len()));
    encode_into(&mut out, opcode, tenant, req_id, payload);
    out
}

/// Incrementally decode one frame from the front of `buf`.
///
/// * `Ok(None)` — the buffer holds a valid prefix of a frame; feed more
///   bytes and call again.
/// * `Ok(Some((frame, consumed)))` — one complete frame; the caller
///   should drop the first `consumed` bytes afterwards.  Bytes past
///   `consumed` (a coalesced next frame) are untouched.
/// * `Err(_)` — protocol violation, detected at the earliest byte that
///   proves it (a bad magic byte errors before the header completes).
pub fn decode(buf: &[u8]) -> Result<Option<(Frame<'_>, usize)>, FrameError> {
    for (offset, &byte) in buf.iter().take(MAGIC.len()).enumerate() {
        if byte != MAGIC[offset] {
            return Err(FrameError::BadMagic { byte, offset });
        }
    }
    if buf.len() > 4 && buf[4] != VERSION {
        return Err(FrameError::BadVersion(buf[4]));
    }
    if buf.len() > 5 && Opcode::from_u8(buf[5]).is_none() {
        return Err(FrameError::BadOpcode(buf[5]));
    }
    if buf.len() >= HEADER_LEN {
        let len = u32::from_le_bytes([buf[16], buf[17], buf[18], buf[19]]);
        if len as usize > MAX_PAYLOAD {
            return Err(FrameError::Oversized(len));
        }
        let total = HEADER_LEN + len as usize;
        if buf.len() >= total {
            let opcode = Opcode::from_u8(buf[5]).expect("opcode validated above");
            return Ok(Some((
                Frame {
                    opcode,
                    tenant: u16::from_le_bytes([buf[6], buf[7]]),
                    req_id: u64::from_le_bytes([
                        buf[8], buf[9], buf[10], buf[11], buf[12], buf[13], buf[14], buf[15],
                    ]),
                    payload: &buf[HEADER_LEN..total],
                },
                total,
            )));
        }
    }
    Ok(None)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_preserves_every_field() {
        let buf = encode(Opcode::Submit, 3, 0xDEAD_BEEF_CAFE_F00D, b"harris critical 4.0");
        assert_eq!(buf.len(), encoded_len(19));
        let (frame, consumed) = decode(&buf).unwrap().expect("complete frame");
        assert_eq!(consumed, buf.len());
        assert_eq!(frame.opcode, Opcode::Submit);
        assert_eq!(frame.tenant, 3);
        assert_eq!(frame.req_id, 0xDEAD_BEEF_CAFE_F00D);
        assert_eq!(frame.payload, b"harris critical 4.0");
    }

    #[test]
    fn empty_payload_roundtrips() {
        let buf = encode(Opcode::Defrag, 0, 7, b"");
        let (frame, consumed) = decode(&buf).unwrap().expect("complete frame");
        assert_eq!(consumed, HEADER_LEN);
        assert_eq!(frame.opcode, Opcode::Defrag);
        assert!(frame.payload.is_empty());
    }

    #[test]
    fn truncated_prefixes_need_more_bytes() {
        let buf = encode(Opcode::Stats, 1, 2, b"SHARDS");
        for cut in 0..buf.len() {
            assert_eq!(decode(&buf[..cut]).unwrap(), None, "cut at {cut}");
        }
        assert!(decode(&buf).unwrap().is_some());
    }

    #[test]
    fn coalesced_frames_decode_in_sequence() {
        let mut buf = encode(Opcode::Stats, 0, 1, b"");
        encode_into(&mut buf, Opcode::Quit, 0, 2, b"");
        let (first, consumed) = decode(&buf).unwrap().expect("first frame");
        assert_eq!(first.opcode, Opcode::Stats);
        assert_eq!(first.req_id, 1);
        let (second, rest) = decode(&buf[consumed..]).unwrap().expect("second frame");
        assert_eq!(second.opcode, Opcode::Quit);
        assert_eq!(second.req_id, 2);
        assert_eq!(consumed + rest, buf.len());
    }

    #[test]
    fn bad_magic_detected_at_first_divergent_byte() {
        assert_eq!(decode(&[0x00]), Err(FrameError::BadMagic { byte: 0x00, offset: 0 }));
        // first byte right, second wrong: caught with only two bytes seen
        assert_eq!(
            decode(&[MAGIC[0], 0xFF]),
            Err(FrameError::BadMagic { byte: 0xFF, offset: 1 })
        );
    }

    #[test]
    fn bad_version_and_opcode_rejected_early() {
        let mut buf = encode(Opcode::Quit, 0, 0, b"");
        buf[4] = 9;
        assert_eq!(decode(&buf[..5]), Err(FrameError::BadVersion(9)));
        let mut buf = encode(Opcode::Quit, 0, 0, b"");
        buf[5] = 0x7F;
        assert_eq!(decode(&buf[..6]), Err(FrameError::BadOpcode(0x7F)));
    }

    #[test]
    fn oversized_length_prefix_rejected_without_buffering() {
        let mut buf = encode(Opcode::Submit, 0, 0, b"x");
        buf[16..20].copy_from_slice(&u32::MAX.to_le_bytes());
        assert_eq!(decode(&buf), Err(FrameError::Oversized(u32::MAX)));
        // ... and the exact boundary is accepted
        let big = vec![0u8; MAX_PAYLOAD];
        let buf = encode(Opcode::Submit, 0, 0, &big);
        let (frame, consumed) = decode(&buf).unwrap().expect("max-size frame");
        assert_eq!(frame.payload.len(), MAX_PAYLOAD);
        assert_eq!(consumed, buf.len());
    }

    #[test]
    fn opcode_bytes_roundtrip_and_classify() {
        for op in [
            Opcode::Submit,
            Opcode::Stats,
            Opcode::Defrag,
            Opcode::Quit,
            Opcode::Shutdown,
            Opcode::Explain,
            Opcode::Watch,
            Opcode::Dump,
            Opcode::ReplyOk,
            Opcode::ReplyBusy,
            Opcode::ReplyErr,
            Opcode::ReplyStats,
            Opcode::ReplyDefrag,
            Opcode::ReplyBye,
            Opcode::ReplyExplain,
            Opcode::ReplyWatch,
            Opcode::ReplyDump,
            Opcode::ReplyEvent,
        ] {
            assert_eq!(Opcode::from_u8(op.as_u8()), Some(op));
            assert_eq!(op.is_request(), op.as_u8() < 0x80);
        }
        assert_eq!(Opcode::from_u8(0x00), None);
        assert_eq!(Opcode::from_u8(0xFF), None);
    }

    #[test]
    fn reply_opcode_mirrors_text_reply_token() {
        assert_eq!(Opcode::for_reply_line("OK seq=0 ntat=1.00"), Opcode::ReplyOk);
        assert_eq!(Opcode::for_reply_line("BUSY tenant=2 queue_depth=32"), Opcode::ReplyBusy);
        assert_eq!(Opcode::for_reply_line("ERR bad app"), Opcode::ReplyErr);
        assert_eq!(Opcode::for_reply_line("STATS served=0"), Opcode::ReplyStats);
        assert_eq!(Opcode::for_reply_line("DEFRAG migrated=0"), Opcode::ReplyDefrag);
        assert_eq!(Opcode::for_reply_line("BYE shutting down"), Opcode::ReplyBye);
        assert_eq!(
            Opcode::for_reply_line("EXPLAIN req=3 lines=2"),
            Opcode::ReplyExplain
        );
        assert_eq!(Opcode::for_reply_line("WATCH ok"), Opcode::ReplyWatch);
        assert_eq!(
            Opcode::for_reply_line("WATCH done events=4 dropped=0"),
            Opcode::ReplyWatch
        );
        assert_eq!(Opcode::for_reply_line("DUMP lines=1"), Opcode::ReplyDump);
        assert_eq!(
            Opcode::for_reply_line("EVENT at=12 shard=0 req=3 completed tenant=1"),
            Opcode::ReplyEvent
        );
        assert_eq!(Opcode::for_reply_line(""), Opcode::ReplyErr);
    }

    #[test]
    fn magic_first_byte_is_outside_ascii() {
        // protocol negotiation hinges on this: no text-protocol line can
        // begin with the binary magic
        assert!(MAGIC[0] >= 0x80);
    }
}
