//! The sharded fabric pool: N independent CGRA fabrics, one router.

use std::collections::BTreeMap;

use crate::abstraction::SliceDemand;
use crate::config::{Config, PlacementPolicyKind};
use crate::dpr::DprMode;
use crate::energy::EnergyReport;
use crate::error::{Error, Result};
use crate::config::QosClass;
use crate::metrics::FragmentationGauge;
use crate::migration::{MigrationReport, MigrationStats};
use crate::noc::NocReport;
use crate::obs::{Decision, DecisionKind, JournalKind, MetricsRegistry, ShardScore};
use crate::qos::{PreemptionRecord, QosStats};
use crate::regions::RegionId;
use crate::scheduler::{CompletionOutcome, Launch, RequestQueue, Scheduler};
use crate::tasks::{AppGraph, AppId, AppRequest, TaskLibrary};

use super::router::{FabricRouter, ShardId, ShardLoad};

/// One independent fabric instance: its own scheduler (and with it its
/// own region manager + DPR engine + migration planner) plus its own
/// ready queue.  Shards share nothing but the router above them.
#[derive(Clone, Debug)]
struct FabricShard {
    id: ShardId,
    sched: Scheduler,
    queue: RequestQueue,
    /// Open (incomplete) requests placed on this shard.
    open: u64,
    /// Cumulative task launches on this shard.
    launches: u64,
}

/// Cumulative pool-level counters.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PoolStats {
    /// Requests routed onto a shard.
    pub placed: u64,
    /// Arrivals rejected because every shard's admission window was
    /// full (`pool.admission_window` > 0 only).
    pub busy_rejections: u64,
    /// Cross-shard rescue compactions: a request's minimal demand fit
    /// no shard right now, so the cheapest shard was defragmented
    /// before placement.
    pub cross_shard_defrags: u64,
}

/// Outcome of [`FabricPool::drain_completion`] — the pool-level
/// analogue of [`crate::scheduler::CompletionOutcome`], with the
/// per-shard queue already advanced on `Done`.
#[derive(Clone, Debug)]
pub enum PoolCompletion {
    /// The completion event was invalidated by a preemption; the marker
    /// is consumed.
    Cancelled,
    /// A migration pushed the finish out to the returned cycle; the
    /// caller should re-queue the event there.
    Stale(u64),
    /// The task completed; `Some` carries the owning request when it
    /// fully completed.
    Done(Option<AppRequest>),
}

/// Point-in-time view of one shard for `STATS`/export surfaces.
#[derive(Clone, Debug)]
pub struct ShardSnapshot {
    /// Shard index.
    pub shard: u32,
    /// Open (incomplete) requests.
    pub open_requests: u64,
    /// Running task count.
    pub running: u64,
    /// Cumulative task launches.
    pub launches: u64,
    /// GLB-slice busy fraction.
    pub glb_utilization: f64,
    /// Array-slice busy fraction.
    pub array_utilization: f64,
    /// Fragmentation gauge.
    pub gauge: FragmentationGauge,
    /// Cumulative live migrations.
    pub migrations: u64,
    /// Joules accumulated by the shard's accountant (0 when `[energy]`
    /// accounting is off).
    pub energy_j: f64,
    /// Windowed average power at the last integration, watts.
    pub power_w: f64,
}

/// A pool of [`Scheduler`]-backed fabric shards behind a
/// [`FabricRouter`].
///
/// With `pool.shards = 1` every call degenerates to the single-fabric
/// path the sims and coordinator always had: one queue, one scheduler,
/// no cross-shard machinery — the golden-equivalence property in
/// `tests/prop_pool.rs` holds the pool to bit-for-bit sameness.
#[derive(Clone, Debug)]
pub struct FabricPool {
    shards: Vec<FabricShard>,
    router: FabricRouter,
    /// Per-shard open-request cap (0 = unbounded).
    window: u64,
    /// request seq → owning shard.
    placed: BTreeMap<u64, ShardId>,
    stats: PoolStats,
    /// Memoized per-app minimal placement demand (componentwise max of
    /// the smallest variant over the app's task graph).
    min_demand: BTreeMap<AppId, SliceDemand>,
    /// Pool-level placement decisions awaiting a
    /// [`FabricPool::take_decisions`] drain; never populated unless
    /// `prov_armed` ([`crate::obs::provenance`]).
    prov_log: Vec<Decision>,
    /// Whether decision provenance is armed (mirrors the shards).
    prov_armed: bool,
}

impl FabricPool {
    /// Pool of `cfg.pool.shards` identical shards built from `cfg`.
    pub fn new(cfg: &Config, lib: TaskLibrary, mode: DprMode) -> Result<FabricPool> {
        cfg.pool.validate()?;
        let cfgs = vec![cfg.clone(); cfg.pool.shards as usize];
        Self::with_shard_configs(
            &cfgs,
            cfg.pool.placement,
            cfg.pool.admission_window,
            lib,
            mode,
        )
    }

    /// Heterogeneous pool: one config per shard (geometry and GLB
    /// presets may differ — the arXiv 2412.08137 provisioning shapes).
    /// Placement and the admission window are pool-level.
    pub fn with_shard_configs(
        cfgs: &[Config],
        placement: PlacementPolicyKind,
        admission_window: u32,
        lib: TaskLibrary,
        mode: DprMode,
    ) -> Result<FabricPool> {
        if cfgs.is_empty() {
            return Err(Error::Config("fabric pool needs at least one shard".into()));
        }
        let shards: Vec<FabricShard> = cfgs
            .iter()
            .enumerate()
            .map(|(i, c)| FabricShard {
                id: ShardId(i as u32),
                sched: Scheduler::new(c, lib.clone(), mode),
                queue: RequestQueue::new(),
                open: 0,
                launches: 0,
            })
            .collect();
        // Pipeline rides along: `placement_demand` skips graph nodes the
        // library cannot resolve, so a plain-Table-1 pool still gets a
        // sane (camera ∪ harris) probe for stray pipeline requests.
        let min_demand = AppId::ALL
            .iter()
            .copied()
            .chain([AppId::Pipeline])
            .map(|app| (app, placement_demand(&lib, app)))
            .collect();
        Ok(FabricPool {
            shards,
            router: FabricRouter::new(placement),
            window: admission_window as u64,
            placed: BTreeMap::new(),
            stats: PoolStats::default(),
            min_demand,
            prov_log: Vec::new(),
            prov_armed: false,
        })
    }

    /// Preload every shard's bitstream cache (fast-DPR warm start).
    pub fn preload_all(&mut self) {
        for s in &mut self.shards {
            s.sched.preload_all();
        }
    }

    /// Number of shards.
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Cumulative pool counters.
    pub fn stats(&self) -> PoolStats {
        self.stats
    }

    /// Which shard holds request `seq`, if it is still open.
    pub fn shard_of(&self, seq: u64) -> Option<ShardId> {
        self.placed.get(&seq).copied()
    }

    /// A shard's scheduler (metrics / tests).
    pub fn scheduler(&self, shard: ShardId) -> Option<&Scheduler> {
        self.shards.get(shard.0 as usize).map(|s| &s.sched)
    }

    /// Open (incomplete) requests across the pool, per placement
    /// accounting.
    pub fn open_requests(&self) -> u64 {
        self.shards.iter().map(|s| s.open).sum()
    }

    /// Open requests per the shard queues themselves (invariant checks:
    /// must agree with [`FabricPool::open_requests`]).
    pub fn queue_open_requests(&self) -> usize {
        self.shards.iter().map(|s| s.queue.open_requests()).sum()
    }

    /// Ready (waiting) tasks across the pool.
    pub fn ready_count(&self) -> usize {
        self.shards.iter().map(|s| s.queue.ready_count()).sum()
    }

    /// Aggregate (glb, array) busy-slice counts.
    pub fn busy_slices(&self) -> (u32, u32) {
        let mut g = 0;
        let mut a = 0;
        for s in &self.shards {
            let mgr = s.sched.regions();
            g += mgr.glb_map().busy_count();
            a += mgr.array_map().busy_count();
        }
        (g, a)
    }

    /// Aggregate (glb, array) slice capacity.
    pub fn total_slices(&self) -> (u32, u32) {
        let mut g = 0;
        let mut a = 0;
        for s in &self.shards {
            let mgr = s.sched.regions();
            g += mgr.glb_map().len();
            a += mgr.array_map().len();
        }
        (g, a)
    }

    /// Aggregate (glb, array) busy fractions.
    pub fn utilization(&self) -> (f64, f64) {
        let (bg, ba) = self.busy_slices();
        let (tg, ta) = self.total_slices();
        (bg as f64 / tg.max(1) as f64, ba as f64 / ta.max(1) as f64)
    }

    /// Mean (glb, array) external fragmentation across shards.
    pub fn fragmentation(&self) -> (f64, f64) {
        let n = self.shards.len().max(1) as f64;
        let mut g = 0.0;
        let mut a = 0.0;
        for s in &self.shards {
            let f = s.sched.regions().fragmentation();
            g += f.0;
            a += f.1;
        }
        (g / n, a / n)
    }

    /// Active placement policy (observability surfaces report it).
    pub fn placement(&self) -> PlacementPolicyKind {
        self.router.policy()
    }

    /// Pool-wide energy report integrated up to `now`: every shard's
    /// accountant advanced and merged (`None` when `[energy]` accounting
    /// is off).
    pub fn energy_report(&mut self, now: u64) -> Option<EnergyReport> {
        let mut merged: Option<EnergyReport> = None;
        for s in &mut self.shards {
            let clock = s.sched.energy().model().clock_mhz();
            if let Some(r) = s.sched.energy_report(now) {
                match merged {
                    None => merged = Some(r),
                    Some(ref mut m) => m.merge(&r, clock),
                }
            }
        }
        merged
    }

    /// Merged NoC contention report across shards (`None` unless
    /// `[noc]` is enabled).
    pub fn noc_report(&self) -> Option<NocReport> {
        let mut merged: Option<NocReport> = None;
        for s in &self.shards {
            if let Some(r) = s.sched.noc_report() {
                match merged {
                    None => merged = Some(r),
                    Some(ref mut m) => m.merge(&r),
                }
            }
        }
        merged
    }

    /// Summed migration counters across shards.
    pub fn migration_stats(&self) -> MigrationStats {
        let mut agg = MigrationStats::default();
        for s in &self.shards {
            let m = s.sched.migration_stats();
            agg.nofit_events += m.nofit_events;
            agg.plans_considered += m.plans_considered;
            agg.plans_committed += m.plans_committed;
            agg.tasks_migrated += m.tasks_migrated;
            agg.migration_cycles += m.migration_cycles;
            agg.rescued_launches += m.rescued_launches;
        }
        agg
    }

    /// Per-shard snapshots (the `STATS shard=<i>` / `pool_json` source).
    pub fn snapshots(&self) -> Vec<ShardSnapshot> {
        self.shards
            .iter()
            .map(|s| {
                let mgr = s.sched.regions();
                let (ug, ua) = mgr.utilization();
                ShardSnapshot {
                    shard: s.id.0,
                    open_requests: s.open,
                    running: s.sched.running_count() as u64,
                    launches: s.launches,
                    glb_utilization: ug,
                    array_utilization: ua,
                    gauge: FragmentationGauge::read(mgr),
                    migrations: s.sched.migration_stats().tasks_migrated,
                    energy_j: s.sched.energy().total_joules(),
                    power_w: s.sched.energy().current_windowed_watts(),
                }
            })
            .collect()
    }

    /// Route and admit one request at cycle `now`.  Returns the placed
    /// shard, or `None` when `pool.admission_window` is set and every
    /// shard is at the cap (the pool-level `BUSY`).
    ///
    /// Multi-shard pools extend the PR 2 rescue machinery across the
    /// pool: when the request's minimal demand fits *no* shard right
    /// now, one compaction pass runs on the cheapest defrag-enabled
    /// shard (fewest running tasks to move) before placement — a task
    /// should not wait fragmented when any shard could be compacted.
    pub fn try_submit(&mut self, req: AppRequest, now: u64) -> Option<ShardId> {
        let demand = self
            .min_demand
            .get(&req.app)
            .copied()
            .unwrap_or_else(|| SliceDemand::new(0, 0));
        if self.window > 0 && self.shards.iter().all(|s| s.open >= self.window) {
            self.stats.busy_rejections += 1;
            if self.prov_armed {
                let shards = score_loads(&self.loads(&demand, req.class, now));
                self.prov_log.push(Decision::new(
                    now,
                    req.seq,
                    DecisionKind::Placement {
                        tenant: req.tenant,
                        chosen: None,
                        rescued: None,
                        shards,
                    },
                ));
            }
            return None;
        }
        let mut loads = self.loads(&demand, req.class, now);
        if self.window > 0 {
            loads.retain(|l| l.open_requests < self.window);
        }
        // Cross-shard defragmentation (multi-shard pools only — with a
        // single shard the scheduler's own NoFit-triggered defrag is
        // already the whole story, and skipping it here keeps
        // `pool.shards = 1` bit-for-bit equivalent to the single-fabric
        // scheduler).
        let mut rescued_to: Option<ShardId> = None;
        if self.shards.len() > 1 && !loads.is_empty() && loads.iter().all(|l| !l.fits_now) {
            if let Some(victim) = self.cheapest_defrag_candidate(&loads, &demand) {
                self.stats.cross_shard_defrags += 1;
                let _ = self.defrag_shard(victim, now);
                loads = self.loads(&demand, req.class, now);
                if self.window > 0 {
                    loads.retain(|l| l.open_requests < self.window);
                }
                // The pass was run *for this request*: when it opened
                // room (and the window still admits the victim), place
                // there directly — scoring by load alone could otherwise
                // queue the request on a shard that still cannot fit it,
                // wasting the migration cycles just charged.
                rescued_to = loads
                    .iter()
                    .find(|l| l.shard == victim && l.fits_now)
                    .map(|l| l.shard);
            }
        }
        let seq = req.seq;
        let tenant = req.tenant;
        let class = req.class;
        let shard = rescued_to.unwrap_or_else(|| self.router.place(tenant, class, &loads));
        if self.prov_armed {
            let mut d = Decision::new(
                now,
                seq,
                DecisionKind::Placement {
                    tenant,
                    chosen: Some(shard.0),
                    rescued: rescued_to.map(|s| s.0),
                    shards: score_loads(&loads),
                },
            );
            d.shard = shard.0;
            self.prov_log.push(d);
        }
        let s = &mut self.shards[shard.0 as usize];
        s.queue.submit(req);
        s.open += 1;
        self.placed.insert(seq, shard);
        self.stats.placed += 1;
        Some(shard)
    }

    /// One scheduling step on every shard (ascending id order).  Returns
    /// every launch tagged with its shard.
    pub fn schedule(&mut self, now: u64) -> Vec<(ShardId, Launch)> {
        let mut out = Vec::new();
        for s in &mut self.shards {
            for launch in s.sched.schedule(&mut s.queue, now) {
                s.launches += 1;
                out.push((s.id, launch));
            }
        }
        out
    }

    /// Complete the task on `region` of `shard` at cycle `now`.  Returns
    /// the owning request when it fully completed.
    pub fn complete(
        &mut self,
        shard: ShardId,
        region: RegionId,
        now: u64,
    ) -> Result<Option<AppRequest>> {
        let s = self
            .shards
            .get_mut(shard.0 as usize)
            .ok_or_else(|| Error::Sched(format!("completion on unknown shard {shard}")))?;
        let inst = s.sched.complete(region, now)?;
        let done = s.queue.mark_complete(inst, now)?;
        if let Some(ref req) = done {
            s.open = s.open.saturating_sub(1);
            self.placed.remove(&req.seq);
        }
        Ok(done)
    }

    /// Drain one completion event on `shard`/`region` in a single pass —
    /// the pool-level analogue of
    /// [`crate::scheduler::Scheduler::drain_completion`], folding in the
    /// per-shard queue bookkeeping that [`FabricPool::complete`] does.
    pub fn drain_completion(
        &mut self,
        shard: ShardId,
        region: RegionId,
        now: u64,
    ) -> Result<PoolCompletion> {
        let s = self
            .shards
            .get_mut(shard.0 as usize)
            .ok_or_else(|| Error::Sched(format!("completion on unknown shard {shard}")))?;
        let inst = match s.sched.drain_completion(region, now)? {
            CompletionOutcome::Cancelled => return Ok(PoolCompletion::Cancelled),
            CompletionOutcome::Stale(finish) => return Ok(PoolCompletion::Stale(finish)),
            CompletionOutcome::Done(inst) => inst,
        };
        let done = s.queue.mark_complete(inst, now)?;
        if let Some(ref req) = done {
            s.open = s.open.saturating_sub(1);
            self.placed.remove(&req.seq);
        }
        Ok(PoolCompletion::Done(done))
    }

    /// Authoritative completion cycle of the task on `shard`/`region`
    /// (migrations push finishes out; see [`Scheduler::finish_of`]).
    pub fn finish_of(&self, shard: ShardId, region: RegionId) -> Option<u64> {
        self.shards
            .get(shard.0 as usize)
            .and_then(|s| s.sched.finish_of(region))
    }

    /// Whether `shard`/`region`'s queued completion event was
    /// invalidated by a preemption (consumes the marker; see
    /// [`crate::scheduler::Scheduler::take_cancelled`]).
    pub fn take_cancelled(&mut self, shard: ShardId, region: RegionId) -> bool {
        self.shards
            .get_mut(shard.0 as usize)
            .map(|s| s.sched.take_cancelled(region))
            .unwrap_or(false)
    }

    /// Drain every shard's eviction records since the last call, tagged
    /// with the shard (ascending shard order).
    pub fn take_preemptions(&mut self) -> Vec<(ShardId, PreemptionRecord)> {
        let mut out = Vec::new();
        for s in &mut self.shards {
            for p in s.sched.take_preemptions() {
                out.push((s.id, p));
            }
        }
        out
    }

    /// Arm (or disarm) observability-instant collection on every
    /// shard's scheduler ([`Scheduler::set_obs`]).
    pub fn set_obs(&mut self, armed: bool) {
        for s in &mut self.shards {
            s.sched.set_obs(armed);
        }
    }

    /// Drain every shard's journal instants (defrag passes, task
    /// migrations) since the last call, tagged with the shard index
    /// (ascending shard order).  Always empty while disarmed.
    pub fn take_obs_events(&mut self) -> Vec<(u32, u64, JournalKind)> {
        let mut out = Vec::new();
        for s in &mut self.shards {
            for (at, kind) in s.sched.take_obs_events() {
                out.push((s.id.0, at, kind));
            }
        }
        out
    }

    /// Arm (or disarm) decision-provenance collection pool-wide: the
    /// router's placement choices plus every shard scheduler's choice
    /// points ([`Scheduler::set_provenance`]).
    pub fn set_provenance(&mut self, armed: bool) {
        self.prov_armed = armed;
        for s in &mut self.shards {
            s.sched.set_provenance(armed);
        }
    }

    /// Drain the pool's placement decisions plus every shard's
    /// scheduler decisions since the last call, shard-stamped
    /// (placements first, then shards in ascending order).  Always
    /// empty while disarmed.
    pub fn take_decisions(&mut self) -> Vec<Decision> {
        let mut out = std::mem::take(&mut self.prov_log);
        for s in &mut self.shards {
            for mut d in s.sched.take_decisions() {
                d.shard = s.id.0;
                out.push(d);
            }
        }
        out
    }

    /// Export every shard's cumulative subsystem counters into an
    /// observability registry, shard-labelled
    /// ([`Scheduler::export_metrics`]).
    pub fn export_metrics(&self, reg: &MetricsRegistry) {
        for s in &self.shards {
            s.sched.export_metrics(reg, Some(s.id.0));
        }
    }

    /// Summed preemption counters across shards ([`crate::qos`]).
    pub fn qos_stats(&self) -> QosStats {
        let mut agg = QosStats::default();
        for s in &self.shards {
            let q = s.sched.qos_stats();
            agg.preemptions += q.preemptions;
            agg.victims_evicted += q.victims_evicted;
            agg.victims_resumed += q.victims_resumed;
            agg.preempt_cycles += q.preempt_cycles;
            agg.rescued_by_preemption += q.rescued_by_preemption;
        }
        agg
    }

    /// Force one compaction pass on `shard` (control-plane and
    /// cross-shard rescue path).
    pub fn defrag_shard(&mut self, shard: ShardId, now: u64) -> Result<MigrationReport> {
        let s = self
            .shards
            .get_mut(shard.0 as usize)
            .ok_or_else(|| Error::Sched(format!("defrag of unknown shard {shard}")))?;
        Ok(s.sched.defrag_now(now))
    }

    // ------------------------------------------------------------ internals

    /// Point-in-time router inputs for every shard.
    fn loads(&self, demand: &SliceDemand, class: QosClass, now: u64) -> Vec<ShardLoad> {
        let energy_aware = self.router.policy() == PlacementPolicyKind::EnergyAware;
        self.shards
            .iter()
            .map(|s| {
                let mgr = s.sched.regions();
                ShardLoad {
                    shard: s.id,
                    open_requests: s.open,
                    busy_array: mgr.array_map().busy_count(),
                    glb_slices: mgr.glb_map().len(),
                    array_slices: mgr.array_map().len(),
                    feasible: mgr.can_ever_fit(demand),
                    fits_now: mgr.can_fit_now(demand),
                    // scored only under the energy-aware policy; skip
                    // the model walk otherwise
                    marginal_pj: if energy_aware {
                        s.sched.marginal_placement_pj(demand)
                    } else {
                        0.0
                    },
                    // scored only for Critical requests ([`crate::qos`])
                    be_runway: if class == QosClass::Critical {
                        s.sched.lower_class_runway(class, now)
                    } else {
                        0
                    },
                    // 0.0 on every shard unless `[noc]` is armed
                    corridor_pressure: mgr.corridor_pressure(),
                }
            })
            .collect()
    }

    /// The shard whose rescue compaction is cheapest: defrag-enabled,
    /// actually fragmented, *able to host the demand after a full
    /// compaction* (free slices ≥ demand in both classes — without this
    /// a saturated pool would pause and relocate running tasks with
    /// zero chance of placing the request), fewest running tasks to
    /// relocate (lowest id breaks ties).
    fn cheapest_defrag_candidate(
        &self,
        loads: &[ShardLoad],
        demand: &SliceDemand,
    ) -> Option<ShardId> {
        loads
            .iter()
            .filter(|l| {
                let s = &self.shards[l.shard.0 as usize];
                let mgr = s.sched.regions();
                let frag = mgr.fragmentation();
                s.sched.defrag_enabled()
                    && (frag.0 > 0.0 || frag.1 > 0.0)
                    && mgr.glb_map().free_count() >= demand.glb_slices
                    && mgr.array_map().free_count() >= demand.array_slices
            })
            .min_by_key(|l| {
                (
                    self.shards[l.shard.0 as usize].sched.running_count(),
                    l.shard.0,
                )
            })
            .map(|l| l.shard)
    }
}

/// Provenance view of the router's scoring inputs
/// ([`crate::obs::provenance`]).
fn score_loads(loads: &[ShardLoad]) -> Vec<ShardScore> {
    loads
        .iter()
        .map(|l| ShardScore {
            shard: l.shard.0,
            open: l.open_requests,
            feasible: l.feasible,
            fits_now: l.fits_now,
            busy: l.busy_array as f64 / l.array_slices.max(1) as f64,
            corridor: l.corridor_pressure,
            marginal_pj: l.marginal_pj,
            be_runway: l.be_runway,
        })
        .collect()
}

/// Componentwise max, over an app's task graph, of each task's smallest
/// variant demand — the minimal footprint any schedule of the app needs
/// at some point, and the probe the router scores shards against.
fn placement_demand(lib: &TaskLibrary, app: AppId) -> SliceDemand {
    let g = AppGraph::of(app);
    let mut d = SliceDemand::new(0, 0);
    for t in &g.nodes {
        if let Ok(spec) = lib.get(t) {
            let s = &spec.smallest().demand;
            d = SliceDemand::new(
                d.glb_slices.max(s.glb_slices),
                d.array_slices.max(s.array_slices),
            );
        }
    }
    d
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{presets, DefragPolicyKind, RegionPolicyKind, SchedulerPolicyKind};

    fn pool(shards: u32, placement: PlacementPolicyKind) -> FabricPool {
        let cfg = presets::pool_scenario(shards, placement);
        let mut p = FabricPool::new(&cfg, TaskLibrary::table1(), DprMode::Fast).unwrap();
        p.preload_all();
        p
    }

    fn req(seq: u64, tenant: u32, app: AppId) -> AppRequest {
        AppRequest::new(seq, tenant, app, 0)
    }

    #[test]
    fn single_shard_submit_schedule_complete_cycle() {
        let mut p = pool(1, PlacementPolicyKind::LeastLoaded);
        assert_eq!(p.shard_count(), 1);
        assert_eq!(p.try_submit(req(0, 3, AppId::Harris), 0), Some(ShardId(0)));
        assert_eq!(p.shard_of(0), Some(ShardId(0)));
        let launches = p.schedule(0);
        assert_eq!(launches.len(), 1);
        let (shard, l) = (&launches[0].0, launches[0].1.clone());
        assert_eq!(*shard, ShardId(0));
        assert!(p.finish_of(ShardId(0), l.region).is_some());
        let done = p.complete(ShardId(0), l.region, l.finish).unwrap();
        assert_eq!(done.expect("harris is one task").seq, 0);
        assert_eq!(p.open_requests(), 0);
        assert_eq!(p.queue_open_requests(), 0);
        assert_eq!(p.shard_of(0), None);
        assert_eq!(p.stats().placed, 1);
    }

    #[test]
    fn least_loaded_spreads_equal_requests_across_shards() {
        let mut p = pool(2, PlacementPolicyKind::LeastLoaded);
        let a = p.try_submit(req(0, 2, AppId::Camera), 0).unwrap();
        let b = p.try_submit(req(1, 2, AppId::Camera), 0).unwrap();
        assert_eq!(a, ShardId(0));
        assert_eq!(b, ShardId(1), "second request must go to the idle shard");
        let launches = p.schedule(0);
        assert_eq!(launches.len(), 2);
        assert_ne!(launches[0].0, launches[1].0);
    }

    #[test]
    fn sticky_placement_pins_tenants() {
        let mut p = pool(2, PlacementPolicyKind::Sticky);
        let first = p.try_submit(req(0, 1, AppId::Harris), 0).unwrap();
        for seq in 1..4 {
            assert_eq!(p.try_submit(req(seq, 1, AppId::Harris), 0), Some(first));
        }
        // another tenant lands on the other shard (least-loaded first hop)
        let other = p.try_submit(req(9, 2, AppId::Harris), 0).unwrap();
        assert_ne!(other, first);
    }

    /// Sticky fallback end-to-end: a tenant whose pinned shard is
    /// saturated (window-filtered out of the placement loads) must
    /// overflow deterministically to the other shard, keep the pin, and
    /// resume affinity once the pinned shard drains — even when the
    /// pinned shard is then the *busier* choice.
    #[test]
    fn sticky_saturated_pin_falls_back_then_resticks() {
        let mut cfg = presets::pool_scenario(2, PlacementPolicyKind::Sticky);
        cfg.pool.admission_window = 2;
        let mut p = FabricPool::new(&cfg, TaskLibrary::table1(), DprMode::Fast).unwrap();
        p.preload_all();

        // tenant 1 pins shard 0 and fills its admission window
        assert_eq!(p.try_submit(req(0, 1, AppId::Harris), 0), Some(ShardId(0)));
        assert_eq!(p.try_submit(req(1, 1, AppId::Harris), 0), Some(ShardId(0)));
        // pinned shard saturated: both overflow requests fall back to
        // shard 1, deterministically, without disturbing the pin
        assert_eq!(p.try_submit(req(2, 1, AppId::Harris), 0), Some(ShardId(1)));
        assert_eq!(p.try_submit(req(3, 1, AppId::Harris), 0), Some(ShardId(1)));
        // every window full: pool-level BUSY
        assert_eq!(p.try_submit(req(4, 1, AppId::Harris), 0), None);

        // drain everything
        let launches = p.schedule(0);
        for (shard, l) in &launches {
            p.complete(*shard, l.region, l.finish).unwrap();
        }
        let more = p.schedule(1_000_000_000);
        for (shard, l) in &more {
            p.complete(*shard, l.region, l.finish).unwrap();
        }
        assert_eq!(p.open_requests(), 0);

        // load shard 1 less than shard 0 via another tenant, then show
        // tenant 1 still resticks to shard 0 (affinity beats load)
        assert_eq!(p.try_submit(req(10, 2, AppId::Harris), 0), Some(ShardId(0)));
        assert_eq!(
            p.try_submit(req(11, 1, AppId::Harris), 0),
            Some(ShardId(0)),
            "pin must resume once the shard is back under the window"
        );
    }

    #[test]
    fn admission_window_rejects_only_when_every_shard_is_full() {
        let mut cfg = presets::pool_scenario(2, PlacementPolicyKind::LeastLoaded);
        cfg.pool.admission_window = 1;
        let mut p = FabricPool::new(&cfg, TaskLibrary::table1(), DprMode::Fast).unwrap();
        assert!(p.try_submit(req(0, 0, AppId::Harris), 0).is_some());
        assert!(p.try_submit(req(1, 1, AppId::Harris), 0).is_some());
        assert_eq!(p.try_submit(req(2, 2, AppId::Harris), 0), None);
        assert_eq!(p.stats().busy_rejections, 1);
        // completing one request reopens the window
        let launches = p.schedule(0);
        let (shard, l) = (launches[0].0, launches[0].1.clone());
        p.complete(shard, l.region, l.finish).unwrap();
        assert!(p.try_submit(req(3, 2, AppId::Harris), l.finish).is_some());
    }

    /// Fragment shard 0 and saturate shard 1, then submit a task that
    /// fits nowhere: the pool must defragment the cheaper shard (0: two
    /// running tasks vs four) and place the request there.
    #[test]
    fn cross_shard_defrag_rescues_a_nofit_everywhere_request() {
        let mut cfg = presets::pool_scenario(2, PlacementPolicyKind::LeastLoaded);
        cfg.scheduler.policy = SchedulerPolicyKind::FcfsFirstFit;
        cfg.scheduler.defrag_policy = DefragPolicyKind::Greedy;
        cfg.scheduler.defrag_threshold = 0.25;
        assert_eq!(cfg.scheduler.region_policy, RegionPolicyKind::FlexibleShape);
        let mut p = FabricPool::new(&cfg, TaskLibrary::table1(), DprMode::Fast).unwrap();
        p.preload_all();

        // 8 harris-a (2 array slices each): least-loaded alternates the
        // placements, 4 per shard, filling both arrays.
        let mut seq = 0;
        for _ in 0..8 {
            p.try_submit(req(seq, 3, AppId::Harris), 0).unwrap();
            seq += 1;
        }
        let launches = p.schedule(0);
        assert_eq!(launches.len(), 8);
        // free the 2nd and 4th launch on shard 0 only: array holes
        // {2,3} and {6,7} — fragmented, while shard 1 stays full
        let on_zero: Vec<_> =
            launches.iter().filter(|(s, _)| *s == ShardId(0)).collect();
        assert_eq!(on_zero.len(), 4);
        for i in [1usize, 3] {
            let (s, l) = on_zero[i];
            p.complete(*s, l.region, 100).unwrap();
        }
        let frag0 = p.scheduler(ShardId(0)).unwrap().regions().fragmentation();
        assert!(frag0.1 >= 0.25, "shard 0 must be fragmented: {frag0:?}");

        // camera-a needs 4 contiguous array slices: fits neither the
        // scattered holes of shard 0 nor full shard 1
        let placed = p.try_submit(req(99, 2, AppId::Camera), 100).unwrap();
        assert_eq!(placed, ShardId(0), "rescue places on the compacted shard");
        assert_eq!(p.stats().cross_shard_defrags, 1);
        assert!(p.migration_stats().tasks_migrated >= 1);
        let launches = p.schedule(100);
        assert_eq!(launches.len(), 1, "camera must launch after the rescue");
        assert_eq!(launches[0].0, ShardId(0));
    }

    #[test]
    fn snapshots_and_aggregates_are_coherent() {
        let mut p = pool(2, PlacementPolicyKind::LeastLoaded);
        p.try_submit(req(0, 2, AppId::Camera), 0).unwrap();
        p.try_submit(req(1, 3, AppId::Harris), 0).unwrap();
        let launches = p.schedule(0);
        assert_eq!(launches.len(), 2);
        let snaps = p.snapshots();
        assert_eq!(snaps.len(), 2);
        assert_eq!(snaps.iter().map(|s| s.running).sum::<u64>(), 2);
        assert_eq!(snaps.iter().map(|s| s.launches).sum::<u64>(), 2);
        let (ug, ua) = p.utilization();
        assert!(ug > 0.0 && ua > 0.0);
        let (bg, ba) = p.busy_slices();
        let (tg, ta) = p.total_slices();
        assert_eq!((tg, ta), (64, 16), "two default shards");
        assert!(bg <= tg && ba <= ta);
        assert_eq!(p.open_requests(), 2);
        assert_eq!(p.queue_open_requests(), 2);
    }

    #[test]
    fn heterogeneous_shards_build_and_best_fit_prefers_tight_shape() {
        let small = presets::test_small(); // 4 array slices, 8 banks
        let big = presets::cloud_scenario(RegionPolicyKind::FlexibleShape);
        let mut p = FabricPool::with_shard_configs(
            &[big, small],
            PlacementPolicyKind::BestFit,
            0,
            TaskLibrary::table1(),
            DprMode::Fast,
        )
        .unwrap();
        assert_eq!(p.shard_count(), 2);
        // harris-a (4 glb, 2 array) fits the small shard, which is the
        // tighter shape
        assert_eq!(p.try_submit(req(0, 3, AppId::Harris), 0), Some(ShardId(1)));
        assert_eq!(p.schedule(0).len(), 1);
    }

    #[test]
    fn pool_preemption_invalidates_events_and_resumes_victims() {
        let mut cfg = presets::pool_scenario(1, PlacementPolicyKind::LeastLoaded);
        cfg.qos.enabled = true; // EDF + preemption defaults
        let mut p = FabricPool::new(&cfg, TaskLibrary::table1(), DprMode::Fast).unwrap();
        p.preload_all();
        // BestEffort harris grabs the fastest variant
        p.try_submit(req(0, 3, AppId::Harris), 0).unwrap();
        let l1 = p.schedule(0);
        assert_eq!(l1.len(), 1);
        let (shard, victim) = (l1[0].0, l1[0].1.clone());
        // a Critical camera evicts it
        p.try_submit(req(1, 2, AppId::Camera).with_qos(QosClass::Critical, None), 10)
            .unwrap();
        let l2 = p.schedule(10);
        assert_eq!(l2.len(), 1, "preemption must rescue the critical launch");
        assert_eq!(p.qos_stats().victims_evicted, 1);
        let pre = p.take_preemptions();
        assert_eq!(pre.len(), 1);
        assert_eq!(pre[0].0, shard);
        assert_eq!(pre[0].1.victim_region, victim.region);
        // the stale completion event is invalidated exactly once
        assert!(p.take_cancelled(shard, victim.region));
        assert!(!p.take_cancelled(shard, victim.region));
        // drain: camera completes, the victim resumes and completes
        p.complete(shard, l2[0].1.region, l2[0].1.finish).unwrap();
        let l3 = p.schedule(l2[0].1.finish);
        assert_eq!(l3.len(), 1, "checkpointed victim resumes");
        p.complete(shard, l3[0].1.region, l3[0].1.finish).unwrap();
        assert_eq!(p.open_requests(), 0);
        assert_eq!(p.qos_stats().victims_resumed, 1);
        assert_eq!(p.busy_slices(), (0, 0), "preempt/resume conserves slices");
    }

    #[test]
    fn provenance_tags_placement_and_shard_decisions() {
        let mut p = pool(2, PlacementPolicyKind::LeastLoaded);
        p.set_provenance(true);
        p.try_submit(req(0, 2, AppId::Camera), 0).unwrap();
        p.try_submit(req(1, 2, AppId::Camera), 0).unwrap();
        p.schedule(0);
        let ds = p.take_decisions();
        let placements: Vec<_> = ds
            .iter()
            .filter(|d| matches!(d.kind, DecisionKind::Placement { .. }))
            .collect();
        assert_eq!(placements.len(), 2, "one placement decision per submit");
        match &placements[1].kind {
            DecisionKind::Placement { chosen, rescued, shards, .. } => {
                assert_eq!(*chosen, Some(1), "least-loaded sends #1 to the idle shard");
                assert_eq!(*rescued, None);
                assert_eq!(shards.len(), 2, "every shard is scored");
            }
            other => panic!("unexpected kind {other:?}"),
        }
        // shard schedulers' variant decisions arrive shard-stamped
        let variant_shards: std::collections::BTreeSet<u32> = ds
            .iter()
            .filter(|d| matches!(d.kind, DecisionKind::Variant { .. }))
            .map(|d| d.shard)
            .collect();
        assert_eq!(variant_shards.len(), 2, "both shards launched: {ds:?}");
        assert!(p.take_decisions().is_empty(), "drain empties the logs");
    }

    #[test]
    fn provenance_records_busy_rejection_as_unplaced() {
        let mut cfg = presets::pool_scenario(2, PlacementPolicyKind::LeastLoaded);
        cfg.pool.admission_window = 1;
        let mut p = FabricPool::new(&cfg, TaskLibrary::table1(), DprMode::Fast).unwrap();
        p.set_provenance(true);
        p.try_submit(req(0, 0, AppId::Harris), 0).unwrap();
        p.try_submit(req(1, 1, AppId::Harris), 0).unwrap();
        assert_eq!(p.try_submit(req(2, 2, AppId::Harris), 0), None);
        let ds = p.take_decisions();
        let rejected = ds.iter().find(|d| d.req == 2).expect("rejection must be recorded");
        match &rejected.kind {
            DecisionKind::Placement { chosen, .. } => assert_eq!(*chosen, None),
            other => panic!("unexpected kind {other:?}"),
        }
    }

    #[test]
    fn complete_on_unknown_shard_errors() {
        let mut p = pool(1, PlacementPolicyKind::LeastLoaded);
        assert!(p.complete(ShardId(9), RegionId(0), 0).is_err());
        assert!(p.defrag_shard(ShardId(9), 0).is_err());
        assert!(p.finish_of(ShardId(9), RegionId(0)).is_none());
    }
}
