//! Placement routing across fabric shards.
//!
//! The router is deliberately stateless about fabric internals: the pool
//! hands it a point-in-time [`ShardLoad`] per shard and it returns the
//! shard the request should land on.  All scoring is deterministic
//! (total orders with shard-id tie-breaks), so pool simulations stay
//! reproducible run-to-run.

use std::collections::BTreeMap;
use std::fmt;

use crate::config::{PlacementPolicyKind, QosClass};

/// Identity of one fabric shard within a pool.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct ShardId(pub u32);

impl fmt::Display for ShardId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "S{}", self.0)
    }
}

/// Point-in-time placement inputs for one shard.
#[derive(Clone, Copy, Debug)]
pub struct ShardLoad {
    /// Which shard.
    pub shard: ShardId,
    /// Open (incomplete) requests currently placed on the shard.
    pub open_requests: u64,
    /// Busy array slices — the compute-pressure proxy.
    pub busy_array: u32,
    /// Total GLB slices (best-fit shape scoring).
    pub glb_slices: u32,
    /// Total array slices (best-fit shape scoring).
    pub array_slices: u32,
    /// Whether the shard's geometry can *ever* host the request's
    /// minimal demand ([`crate::regions::RegionManager::can_ever_fit`]).
    pub feasible: bool,
    /// Whether that demand fits *right now*
    /// ([`crate::regions::RegionManager::can_fit_now`]).
    pub fits_now: bool,
    /// Marginal pJ/cycle the shard would add by hosting the demand
    /// ([`crate::scheduler::Scheduler::marginal_placement_pj`]) — the
    /// energy-aware placement score.  0 for the other policies' inputs
    /// is harmless: they never read it.
    pub marginal_pj: f64,
    /// Longest remaining runway (cycles) of running tasks *below* the
    /// placed request's class
    /// ([`crate::scheduler::Scheduler::lower_class_runway`]) — the
    /// class-aware placement score: a Critical request avoids shards
    /// where long-runway BestEffort work stands in its way.  0 for
    /// non-Critical requests (never read).
    pub be_runway: u64,
    /// Worst corridor oversubscription currently on the shard
    /// ([`crate::regions::RegionManager::corridor_pressure`]) — the
    /// comm-aware pool signal: otherwise-equal shards tie-break toward
    /// the colder interconnect.  0.0 with `[noc]` off on every shard,
    /// so the legacy orders are untouched.
    pub corridor_pressure: f64,
}

/// Scores ready requests across the shards of a [`super::FabricPool`].
#[derive(Clone, Debug)]
pub struct FabricRouter {
    policy: PlacementPolicyKind,
    /// tenant → shard affinity (sticky placement).
    sticky: BTreeMap<u32, ShardId>,
}

impl FabricRouter {
    /// Router under the given placement policy.
    pub fn new(policy: PlacementPolicyKind) -> FabricRouter {
        FabricRouter { policy, sticky: BTreeMap::new() }
    }

    /// Active placement policy.
    pub fn policy(&self) -> PlacementPolicyKind {
        self.policy
    }

    /// Choose a shard for `tenant`'s request among `loads` (must be
    /// non-empty).  Infeasible shards lose to feasible ones under every
    /// policy; within the feasible set the policy's total order decides,
    /// with the shard id as the final deterministic tie-break.
    ///
    /// A **Critical** request overrides the configured policy (sticky
    /// affinity included) with the class-aware order: shards that can
    /// host the demand right now, then the shortest lower-class runway
    /// (`be_runway`), then least-loaded — Critical work lands where it
    /// will not queue behind (or have to preempt) long-running
    /// BestEffort tasks.  With the QoS subsystem disabled every request
    /// is BestEffort and this path never runs.
    pub fn place(&mut self, tenant: u32, class: QosClass, loads: &[ShardLoad]) -> ShardId {
        debug_assert!(!loads.is_empty(), "placement over an empty pool");
        if loads.len() == 1 {
            return loads[0].shard;
        }
        if class == QosClass::Critical {
            return Self::critical_first(loads);
        }
        match self.policy {
            PlacementPolicyKind::LeastLoaded => Self::least_loaded(loads),
            PlacementPolicyKind::BestFit => Self::best_fit(loads),
            PlacementPolicyKind::EnergyAware => Self::energy_aware(loads),
            PlacementPolicyKind::Sticky => {
                if let Some(&s) = self.sticky.get(&tenant) {
                    match loads.iter().find(|l| l.shard == s) {
                        Some(l) if l.feasible => return s,
                        // present but can never host the demand: the
                        // pin is permanently wrong — re-pin below
                        Some(_) => {}
                        // transiently absent (admission window full):
                        // overflow this one request least-loaded but
                        // keep the pin — affinity is a permanent
                        // contract, not a per-request race
                        None => return Self::least_loaded(loads),
                    }
                }
                let s = Self::least_loaded(loads);
                self.sticky.insert(tenant, s);
                s
            }
        }
    }

    /// Class-aware order for Critical requests: fits-now first, then
    /// shortest lower-class runway, then least-loaded order.
    fn critical_first(loads: &[ShardLoad]) -> ShardId {
        loads
            .iter()
            .min_by(|a, b| {
                (!a.feasible, !a.fits_now, a.be_runway, a.open_requests, a.busy_array)
                    .cmp(&(!b.feasible, !b.fits_now, b.be_runway, b.open_requests, b.busy_array))
                    .then(a.corridor_pressure.total_cmp(&b.corridor_pressure))
                    .then(a.shard.0.cmp(&b.shard.0))
            })
            .expect("non-empty loads")
            .shard
    }

    /// Fewest open requests, then fewest busy array slices, then the
    /// coldest interconnect, then id.
    fn least_loaded(loads: &[ShardLoad]) -> ShardId {
        loads
            .iter()
            .min_by(|a, b| {
                (!a.feasible, a.open_requests, a.busy_array)
                    .cmp(&(!b.feasible, b.open_requests, b.busy_array))
                    .then(a.corridor_pressure.total_cmp(&b.corridor_pressure))
                    .then(a.shard.0.cmp(&b.shard.0))
            })
            .expect("non-empty loads")
            .shard
    }

    /// Smallest marginal power first, among shards that can host the
    /// demand right now (queueing onto a shard that cannot fit wastes
    /// the energy argument); least-loaded order breaks exact ties, so
    /// requests consolidate deterministically and drained shards stay
    /// in deep sleep.
    fn energy_aware(loads: &[ShardLoad]) -> ShardId {
        loads
            .iter()
            .min_by(|a, b| {
                (!a.feasible, !a.fits_now)
                    .cmp(&(!b.feasible, !b.fits_now))
                    .then(a.marginal_pj.total_cmp(&b.marginal_pj))
                    .then_with(|| {
                        (a.open_requests, a.busy_array)
                            .cmp(&(b.open_requests, b.busy_array))
                            .then(a.corridor_pressure.total_cmp(&b.corridor_pressure))
                            .then(a.shard.0.cmp(&b.shard.0))
                    })
            })
            .expect("non-empty loads")
            .shard
    }

    /// Tightest feasible shape (smallest array, then GLB, capacity);
    /// least-loaded order breaks ties, so a homogeneous pool degenerates
    /// to least-loaded.
    fn best_fit(loads: &[ShardLoad]) -> ShardId {
        loads
            .iter()
            .min_by(|a, b| {
                (!a.feasible, a.array_slices, a.glb_slices, a.open_requests, a.busy_array)
                    .cmp(&(!b.feasible, b.array_slices, b.glb_slices, b.open_requests, b.busy_array))
                    .then(a.corridor_pressure.total_cmp(&b.corridor_pressure))
                    .then(a.shard.0.cmp(&b.shard.0))
            })
            .expect("non-empty loads")
            .shard
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn load(shard: u32, open: u64, busy: u32) -> ShardLoad {
        ShardLoad {
            shard: ShardId(shard),
            open_requests: open,
            busy_array: busy,
            glb_slices: 32,
            array_slices: 8,
            feasible: true,
            fits_now: true,
            marginal_pj: 0.0,
            be_runway: 0,
            corridor_pressure: 0.0,
        }
    }

    #[test]
    fn single_shard_short_circuits() {
        let mut r = FabricRouter::new(PlacementPolicyKind::Sticky);
        assert_eq!(r.place(3, QosClass::BestEffort, &[load(0, 99, 8)]), ShardId(0));
        // the short-circuit must not record affinity state
        assert!(r.sticky.is_empty());
    }

    #[test]
    fn least_loaded_prefers_fewest_open_then_busy_then_id() {
        let mut r = FabricRouter::new(PlacementPolicyKind::LeastLoaded);
        assert_eq!(r.place(0, QosClass::BestEffort, &[load(0, 2, 0), load(1, 1, 8)]), ShardId(1));
        assert_eq!(r.place(0, QosClass::BestEffort, &[load(0, 1, 4), load(1, 1, 2)]), ShardId(1));
        assert_eq!(r.place(0, QosClass::BestEffort, &[load(0, 1, 4), load(1, 1, 4)]), ShardId(0));
    }

    #[test]
    fn infeasible_shards_lose_under_every_policy() {
        for policy in PlacementPolicyKind::ALL {
            let mut r = FabricRouter::new(policy);
            let mut a = load(0, 0, 0);
            a.feasible = false;
            let b = load(1, 50, 8);
            assert_eq!(r.place(0, QosClass::BestEffort, &[a, b]), ShardId(1), "{policy:?}");
        }
    }

    #[test]
    fn best_fit_picks_tightest_feasible_shape() {
        let mut r = FabricRouter::new(PlacementPolicyKind::BestFit);
        let big = ShardLoad { glb_slices: 64, array_slices: 16, ..load(0, 0, 0) };
        let small = load(1, 3, 6);
        assert_eq!(r.place(0, QosClass::BestEffort, &[big, small]), ShardId(1));
        // homogeneous shapes degenerate to least-loaded
        assert_eq!(r.place(0, QosClass::BestEffort, &[load(0, 5, 0), load(1, 2, 0)]), ShardId(1));
    }

    #[test]
    fn energy_aware_minimizes_marginal_power_then_consolidates() {
        let mut r = FabricRouter::new(PlacementPolicyKind::EnergyAware);
        // the busier shard has the lower marginal power (its domains are
        // already awake): consolidation wins over spreading
        let awake = ShardLoad { marginal_pj: 100.0, ..load(0, 5, 6) };
        let asleep = ShardLoad { marginal_pj: 600.0, ..load(1, 0, 0) };
        assert_eq!(r.place(0, QosClass::BestEffort, &[awake, asleep]), ShardId(0));
        // ...but a shard that cannot host the demand right now loses
        // regardless of its marginal power
        let mut full = awake;
        full.fits_now = false;
        assert_eq!(r.place(0, QosClass::BestEffort, &[full, asleep]), ShardId(1));
        // exact marginal ties fall back to least-loaded order
        let a = ShardLoad { marginal_pj: 50.0, ..load(0, 3, 0) };
        let b = ShardLoad { marginal_pj: 50.0, ..load(1, 1, 0) };
        assert_eq!(r.place(0, QosClass::BestEffort, &[a, b]), ShardId(1));
    }

    #[test]
    fn critical_requests_avoid_long_runway_best_effort_shards() {
        for policy in PlacementPolicyKind::ALL {
            let mut r = FabricRouter::new(policy);
            // shard 0 looks least-loaded but hosts a long-runway
            // BestEffort task; shard 1 is busier but clear
            let hosting = ShardLoad { be_runway: 1_000_000, ..load(0, 0, 2) };
            let clear = load(1, 3, 4);
            assert_eq!(
                r.place(0, QosClass::Critical, &[hosting, clear]),
                ShardId(1),
                "{policy:?}: critical must avoid the long-runway shard"
            );
            // a BestEffort request on the same loads ignores the runway
            assert_eq!(r.place(0, QosClass::BestEffort, &[hosting, clear]), ShardId(0));
            // ...but a shard that cannot fit right now loses anyway
            let mut full = clear;
            full.fits_now = false;
            assert_eq!(
                r.place(0, QosClass::Critical, &[hosting, full]),
                ShardId(0),
                "{policy:?}: fits-now dominates the runway score"
            );
        }
    }

    #[test]
    fn corridor_pressure_breaks_equal_load_ties() {
        // equal open/busy: the colder interconnect wins under every
        // non-sticky policy order
        for policy in [
            PlacementPolicyKind::LeastLoaded,
            PlacementPolicyKind::BestFit,
            PlacementPolicyKind::EnergyAware,
        ] {
            let mut r = FabricRouter::new(policy);
            let hot = ShardLoad { corridor_pressure: 1.4, ..load(0, 2, 4) };
            let cold = ShardLoad { corridor_pressure: 1.0, ..load(1, 2, 4) };
            assert_eq!(r.place(0, QosClass::BestEffort, &[hot, cold]), ShardId(1), "{policy:?}");
            // ...but load still dominates pressure
            let busy_cold = ShardLoad { corridor_pressure: 1.0, ..load(1, 5, 4) };
            assert_eq!(
                r.place(0, QosClass::BestEffort, &[hot, busy_cold]),
                ShardId(0),
                "{policy:?}"
            );
        }
        // critical path: pressure tie-breaks after the runway order
        let mut r = FabricRouter::new(PlacementPolicyKind::LeastLoaded);
        let hot = ShardLoad { corridor_pressure: 2.0, ..load(0, 1, 2) };
        let cold = ShardLoad { corridor_pressure: 1.0, ..load(1, 1, 2) };
        assert_eq!(r.place(0, QosClass::Critical, &[hot, cold]), ShardId(1));
    }

    #[test]
    fn sticky_keeps_tenants_on_their_first_shard() {
        let mut r = FabricRouter::new(PlacementPolicyKind::Sticky);
        let first = r.place(7, QosClass::BestEffort, &[load(0, 3, 0), load(1, 0, 0)]);
        assert_eq!(first, ShardId(1), "first placement is least-loaded");
        // the shard stays pinned even once it is the busier one
        assert_eq!(r.place(7, QosClass::BestEffort, &[load(0, 0, 0), load(1, 9, 8)]), ShardId(1));
        // ...but a shard that cannot host the demand breaks the pin
        let mut pinned = load(1, 9, 8);
        pinned.feasible = false;
        assert_eq!(r.place(7, QosClass::BestEffort, &[load(0, 0, 0), pinned]), ShardId(0));
    }

    #[test]
    fn sticky_repins_after_infeasible_and_the_new_pin_holds() {
        let mut r = FabricRouter::new(PlacementPolicyKind::Sticky);
        assert_eq!(r.place(5, QosClass::BestEffort, &[load(0, 0, 0), load(1, 1, 0)]), ShardId(0), "pin 0");
        // the pinned shard can never host the demand: re-pin least-loaded
        let mut bad = load(0, 0, 0);
        bad.feasible = false;
        assert_eq!(r.place(5, QosClass::BestEffort, &[bad, load(1, 9, 8)]), ShardId(1), "re-pin");
        // the new pin is durable even once shard 0 is feasible and idle
        assert_eq!(r.place(5, QosClass::BestEffort, &[load(0, 0, 0), load(1, 9, 8)]), ShardId(1));
        assert_eq!(r.sticky.get(&5), Some(&ShardId(1)));
    }

    #[test]
    fn sticky_pin_survives_transient_absence_from_loads() {
        let mut r = FabricRouter::new(PlacementPolicyKind::Sticky);
        assert_eq!(r.place(3, QosClass::BestEffort, &[load(0, 1, 0), load(1, 0, 0)]), ShardId(1));
        // the pinned shard is window-filtered out of this placement:
        // the request overflows least-loaded, the pin stays put...
        assert_eq!(r.place(3, QosClass::BestEffort, &[load(0, 4, 0), load(2, 0, 0)]), ShardId(2));
        assert_eq!(r.sticky.get(&3), Some(&ShardId(1)));
        // ...and once the pinned shard is back, affinity resumes
        assert_eq!(r.place(3, QosClass::BestEffort, &[load(0, 0, 0), load(1, 9, 8)]), ShardId(1));
    }
}
