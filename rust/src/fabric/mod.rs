//! Sharded fabric pool: many CGRA fabrics behind one placement router.
//!
//! The paper's abstractions deliberately decouple compilation from
//! allocation: a task ships pre-compiled variants with quantized slice
//! demands, and *where* those slices live is the runtime's choice
//! (§2.2–2.3).  Nothing in that contract limits the runtime to a single
//! fabric — so this module generalizes the serving path from one CGRA to
//! a **pool** of independent fabric instances:
//!
//! * [`FabricPool`] owns N shards, each a full [`crate::scheduler::Scheduler`]
//!   (its own [`crate::regions::RegionManager`], [`crate::dpr::DprEngine`]
//!   and [`crate::migration`] planner) plus its own request queue.
//!   Shards may be heterogeneous (per-shard geometry/GLB presets via
//!   [`FabricPool::with_shard_configs`]) — the provisioning analysis in
//!   arXiv 2412.08137 argues per-fabric resource shapes *should* differ.
//! * [`FabricRouter`] scores ready requests across shards under the
//!   `pool.placement` policy ([`crate::config::PlacementPolicyKind`]):
//!   least-loaded, best-fit-by-shape, or sticky tenant affinity.
//! * Cross-shard rescue: when a request's minimal demand fits no shard
//!   right now, the pool runs one compaction pass of the PR 2 migration
//!   machinery on the cheapest shard before placing (Mestra's
//!   observation that relocating running tasks recovers capacity,
//!   generalized across fabric instances).
//!
//! `pool.shards = 1` is bit-for-bit the single-fabric behavior — the
//! golden-equivalence property test (`tests/prop_pool.rs`) compares
//! event traces against the plain scheduler to keep it that way.
//!
//! The pool simulations ([`crate::sim::run_cloud_pool`],
//! [`crate::sim::run_edge_pool`]) drive this module in virtual time; the
//! TCP coordinator ([`crate::coordinator::Server`]) runs the same
//! sharding live with per-shard leader executors.

mod pool;
mod router;

pub use pool::{FabricPool, PoolCompletion, PoolStats, ShardSnapshot};
pub use router::{FabricRouter, ShardId, ShardLoad};
