//! A strict parser for the TOML subset the config files use.
//!
//! Supported: `[table]` / `[nested.table]` headers, `key = value` pairs,
//! strings (basic, with escapes), integers, floats, booleans, and
//! homogeneous arrays, plus `#` comments.  Unsupported TOML (dates,
//! inline tables, arrays-of-tables, dotted keys) is rejected with a
//! line-numbered error rather than silently misparsed.

use std::collections::BTreeMap;

use crate::error::{Error, Result};

/// Parsed TOML value.
#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    /// Basic string.
    Str(String),
    /// 64-bit integer.
    Int(i64),
    /// 64-bit float.
    Float(f64),
    /// Boolean.
    Bool(bool),
    /// Homogeneous array.
    Arr(Vec<TomlValue>),
    /// Table (from `[header]` sections or the root).
    Table(BTreeMap<String, TomlValue>),
}

impl TomlValue {
    /// Parse a complete document into the root table.
    pub fn parse(text: &str) -> Result<TomlValue> {
        let mut root = BTreeMap::new();
        let mut current_path: Vec<String> = Vec::new();

        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            let loc = || format!("toml:{}", lineno + 1);

            if let Some(header) = line.strip_prefix('[') {
                let header = header
                    .strip_suffix(']')
                    .ok_or_else(|| Error::parse(loc(), "unterminated table header"))?;
                if header.starts_with('[') {
                    return Err(Error::parse(loc(), "arrays of tables are not supported"));
                }
                let path: Vec<String> = header.split('.').map(|s| s.trim().to_string()).collect();
                if path.iter().any(|p| p.is_empty() || !is_bare_key(p)) {
                    return Err(Error::parse(loc(), format!("invalid table name '{header}'")));
                }
                // create intermediate tables
                ensure_table(&mut root, &path, &loc())?;
                current_path = path;
            } else if let Some(eq) = find_unquoted(line, '=') {
                let key = line[..eq].trim();
                if !is_bare_key(key) {
                    return Err(Error::parse(loc(), format!("invalid key '{key}'")));
                }
                let value = parse_value(line[eq + 1..].trim(), &loc())?;
                let table = navigate(&mut root, &current_path).expect("tables pre-created");
                if table.insert(key.to_string(), value).is_some() {
                    return Err(Error::parse(loc(), format!("duplicate key '{key}'")));
                }
            } else {
                return Err(Error::parse(loc(), format!("cannot parse line '{line}'")));
            }
        }
        Ok(TomlValue::Table(root))
    }

    /// Look up a dotted path (`"arch.glb_banks"`).
    pub fn lookup(&self, dotted: &str) -> Option<&TomlValue> {
        let mut cur = self;
        for part in dotted.split('.') {
            match cur {
                TomlValue::Table(m) => cur = m.get(part)?,
                _ => return None,
            }
        }
        Some(cur)
    }

    /// Table field access.
    pub fn get(&self, key: &str) -> Option<&TomlValue> {
        match self {
            TomlValue::Table(m) => m.get(key),
            _ => None,
        }
    }

    /// String payload.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Integer payload.
    pub fn as_int(&self) -> Option<i64> {
        match self {
            TomlValue::Int(i) => Some(*i),
            _ => None,
        }
    }

    /// Float payload (integers coerce).
    pub fn as_float(&self) -> Option<f64> {
        match self {
            TomlValue::Float(f) => Some(*f),
            TomlValue::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    /// Boolean payload.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Array payload.
    pub fn as_arr(&self) -> Option<&[TomlValue]> {
        match self {
            TomlValue::Arr(v) => Some(v),
            _ => None,
        }
    }
}

fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn find_unquoted(line: &str, target: char) -> Option<usize> {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            c if c == target && !in_str => return Some(i),
            _ => {}
        }
    }
    None
}

fn is_bare_key(s: &str) -> bool {
    !s.is_empty()
        && s.chars()
            .all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '-')
}

fn ensure_table<'a>(
    root: &'a mut BTreeMap<String, TomlValue>,
    path: &[String],
    loc: &str,
) -> Result<&'a mut BTreeMap<String, TomlValue>> {
    let mut cur = root;
    for part in path {
        let entry = cur
            .entry(part.clone())
            .or_insert_with(|| TomlValue::Table(BTreeMap::new()));
        match entry {
            TomlValue::Table(m) => cur = m,
            _ => {
                return Err(Error::parse(
                    loc.to_string(),
                    format!("'{part}' is already a non-table value"),
                ))
            }
        }
    }
    Ok(cur)
}

fn navigate<'a>(
    root: &'a mut BTreeMap<String, TomlValue>,
    path: &[String],
) -> Option<&'a mut BTreeMap<String, TomlValue>> {
    let mut cur = root;
    for part in path {
        match cur.get_mut(part) {
            Some(TomlValue::Table(m)) => cur = m,
            _ => return None,
        }
    }
    Some(cur)
}

fn parse_value(text: &str, loc: &str) -> Result<TomlValue> {
    if text.is_empty() {
        return Err(Error::parse(loc.to_string(), "missing value"));
    }
    if let Some(inner) = text.strip_prefix('"') {
        let inner = inner
            .strip_suffix('"')
            .ok_or_else(|| Error::parse(loc.to_string(), "unterminated string"))?;
        return Ok(TomlValue::Str(unescape(inner, loc)?));
    }
    if text.starts_with('[') {
        let inner = text
            .strip_prefix('[')
            .and_then(|t| t.strip_suffix(']'))
            .ok_or_else(|| Error::parse(loc.to_string(), "unterminated array"))?;
        let mut items = Vec::new();
        for part in split_top_level(inner) {
            let part = part.trim();
            if !part.is_empty() {
                items.push(parse_value(part, loc)?);
            }
        }
        return Ok(TomlValue::Arr(items));
    }
    match text {
        "true" => return Ok(TomlValue::Bool(true)),
        "false" => return Ok(TomlValue::Bool(false)),
        _ => {}
    }
    let clean = text.replace('_', "");
    if let Ok(i) = clean.parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = clean.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    Err(Error::parse(
        loc.to_string(),
        format!("cannot parse value '{text}'"),
    ))
}

fn unescape(s: &str, loc: &str) -> Result<String> {
    let mut out = String::with_capacity(s.len());
    let mut chars = s.chars();
    while let Some(c) = chars.next() {
        if c != '\\' {
            out.push(c);
            continue;
        }
        match chars.next() {
            Some('n') => out.push('\n'),
            Some('t') => out.push('\t'),
            Some('r') => out.push('\r'),
            Some('"') => out.push('"'),
            Some('\\') => out.push('\\'),
            other => {
                return Err(Error::parse(
                    loc.to_string(),
                    format!("invalid escape '\\{}'", other.map(String::from).unwrap_or_default()),
                ))
            }
        }
    }
    Ok(out)
}

/// Split an array body on commas not inside strings or nested brackets.
fn split_top_level(s: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let (mut depth, mut in_str, mut start) = (0i32, false, 0usize);
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth -= 1,
            ',' if !in_str && depth == 0 => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&s[start..]);
    parts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_flat_keys() {
        let doc = "a = 1\nb = 2.5\nc = \"hi\"\nd = true\n";
        let v = TomlValue::parse(doc).unwrap();
        assert_eq!(v.get("a").unwrap().as_int(), Some(1));
        assert_eq!(v.get("b").unwrap().as_float(), Some(2.5));
        assert_eq!(v.get("c").unwrap().as_str(), Some("hi"));
        assert_eq!(v.get("d").unwrap().as_bool(), Some(true));
    }

    #[test]
    fn parses_tables_and_nesting() {
        let doc = "[arch]\nbanks = 32\n[workload.cloud]\nrate = 0.5\n";
        let v = TomlValue::parse(doc).unwrap();
        assert_eq!(v.lookup("arch.banks").unwrap().as_int(), Some(32));
        assert_eq!(v.lookup("workload.cloud.rate").unwrap().as_float(), Some(0.5));
    }

    #[test]
    fn parses_arrays() {
        let doc = "xs = [1, 2, 3]\nnames = [\"a\", \"b\"]\nnested = [[1], [2, 3]]\n";
        let v = TomlValue::parse(doc).unwrap();
        assert_eq!(v.get("xs").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("names").unwrap().as_arr().unwrap()[1].as_str(), Some("b"));
        assert_eq!(v.get("nested").unwrap().as_arr().unwrap()[1].as_arr().unwrap().len(), 2);
    }

    #[test]
    fn comments_and_blank_lines() {
        let doc = "# header\n\na = 1 # trailing\nb = \"with # hash\"\n";
        let v = TomlValue::parse(doc).unwrap();
        assert_eq!(v.get("a").unwrap().as_int(), Some(1));
        assert_eq!(v.get("b").unwrap().as_str(), Some("with # hash"));
    }

    #[test]
    fn underscored_numbers() {
        let v = TomlValue::parse("big = 1_000_000\n").unwrap();
        assert_eq!(v.get("big").unwrap().as_int(), Some(1_000_000));
    }

    #[test]
    fn negative_and_float_values() {
        let v = TomlValue::parse("a = -5\nb = -2.5e-3\n").unwrap();
        assert_eq!(v.get("a").unwrap().as_int(), Some(-5));
        assert!((v.get("b").unwrap().as_float().unwrap() + 0.0025).abs() < 1e-12);
    }

    #[test]
    fn rejects_malformed() {
        assert!(TomlValue::parse("a =").is_err());
        assert!(TomlValue::parse("[unclosed\n").is_err());
        assert!(TomlValue::parse("just a line\n").is_err());
        assert!(TomlValue::parse("a = \"unterminated\n").is_err());
        assert!(TomlValue::parse("[[aot]]\n").is_err());
        assert!(TomlValue::parse("a = 1\na = 2\n").is_err());
    }

    #[test]
    fn rejects_table_scalar_conflict() {
        assert!(TomlValue::parse("a = 1\n[a]\nb = 2\n").is_err());
    }

    #[test]
    fn string_escapes() {
        let v = TomlValue::parse(r#"s = "line\nnext\t\"q\"""#).unwrap();
        assert_eq!(v.get("s").unwrap().as_str(), Some("line\nnext\t\"q\""));
    }

    #[test]
    fn int_float_coercion() {
        let v = TomlValue::parse("i = 3\n").unwrap();
        assert_eq!(v.get("i").unwrap().as_float(), Some(3.0));
        assert_eq!(v.get("i").unwrap().as_str(), None);
    }
}
