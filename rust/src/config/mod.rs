//! Configuration system: a TOML-subset parser plus typed schemas.
//!
//! Everything tunable in the reproduction — CGRA geometry, clocks, DPR
//! engine parameters, workload intensities, scheduler policy — lives in a
//! config file so experiments are declarative.  `presets` carries the
//! paper-faithful defaults (Amber-like 32×16 array, 32-bank GLB, 500 MHz).

pub mod presets;
pub mod schema;
pub mod toml;

pub use schema::{
    ArchConfig, CloudWorkloadConfig, Config, DefragPolicyKind, DprConfig, EdgeWorkloadConfig,
    EnergyConfig, MigrationCostModelKind, NocConfig, NocPlacementKind, ObsConfig,
    PlacementPolicyKind, PoolConfig, QosClass, QosConfig, QosPolicyKind, RegionPolicyKind,
    SchedulerConfig,
    SchedulerPolicyKind, ServerConfig, ServerModeKind, WireProtocolKind, WorkloadConfig,
};
pub use toml::TomlValue;
