//! Canonical configuration presets used by examples, benches, and tests.

use crate::config::schema::{
    CloudWorkloadConfig, Config, DefragPolicyKind, EdgeWorkloadConfig, NocPlacementKind,
    PlacementPolicyKind, QosClass, QosPolicyKind, RegionPolicyKind, SchedulerPolicyKind,
    WorkloadConfig,
};
use crate::tasks::AppId;

/// Paper-faithful configuration: Amber-like geometry, flexible-shape
/// regions, greedy scheduler, cloud workload.
pub fn paper_default() -> Config {
    Config::default()
}

/// The paper's cloud scenario (§3.1) under a given region mechanism.
pub fn cloud_scenario(policy: RegionPolicyKind) -> Config {
    let mut cfg = Config::default();
    cfg.scheduler.region_policy = policy;
    cfg.workload = WorkloadConfig::Cloud(CloudWorkloadConfig::default());
    cfg
}

/// The paper's autonomous-system scenario (§3.2).
///
/// Per Fig. 5's caption, the baseline uses AXI4-Lite DPR while the
/// partitioned mechanisms use fast-DPR; the DPR engine choice is made by
/// the simulator from the region policy, not here.
pub fn edge_scenario(policy: RegionPolicyKind) -> Config {
    let mut cfg = Config::default();
    cfg.scheduler.region_policy = policy;
    // Embedded baseline: one standard bitstream per task (the variant
    // library of §2.2 only exists with the proposed abstraction).
    cfg.scheduler.baseline_single_mapping = true;
    // Unit regions sized to the edge task set's variant-a demands
    // (camera a = 4 GLB + 4 array) per "the largest task determines the
    // size" (§2.3).
    cfg.scheduler.unit_glb_slices = 4;
    cfg.scheduler.unit_array_slices = 4;
    cfg.workload = WorkloadConfig::Edge(EdgeWorkloadConfig::default());
    cfg
}

/// Long-running churn scenario: the cloud workload pushed well past
/// saturation (~2.5× the Fig. 4 offered load) so a sustained backlog
/// churns allocations and the slice maps fragment — the workload class
/// the migration subsystem ([`crate::migration`]) exists for.  The
/// defrag policy is the ablation axis (`off` / `greedy` / `cost-aware`);
/// everything else, arrivals included, is identical across policies.
pub fn churn_scenario(policy: RegionPolicyKind, defrag: DefragPolicyKind) -> Config {
    let mut cfg = cloud_scenario(policy);
    cfg.scheduler.defrag_policy = defrag;
    cfg.scheduler.defrag_threshold = 0.1;
    if let WorkloadConfig::Cloud(ref mut c) = cfg.workload {
        c.mean_interarrival_ms = [18.0, 10.0, 14.0, 11.0];
        c.duration_ms = 2_000.0;
        c.seed = 0xC4_12_2026;
    }
    cfg
}

/// Edge churn scenario: the autonomous workload with every event stream
/// firing nearly every frame (period 1–2 instead of 3–7), stacking
/// concurrent tasks until regions churn.  Defrag knobs as in
/// [`churn_scenario`].
pub fn edge_churn_scenario(policy: RegionPolicyKind, defrag: DefragPolicyKind) -> Config {
    let mut cfg = edge_scenario(policy);
    cfg.scheduler.defrag_policy = defrag;
    cfg.scheduler.defrag_threshold = 0.1;
    if let WorkloadConfig::Edge(ref mut e) = cfg.workload {
        e.event_period_frames = (1, 2);
    }
    cfg
}

/// A sharded fabric pool over the cloud scenario: `shards` independent
/// flexible-shape fabrics behind one placement router
/// ([`crate::fabric`]).  `shards = 1` reproduces [`cloud_scenario`]
/// bit-for-bit (the golden-equivalence property in `tests/prop_pool.rs`
/// holds the pool to that).
pub fn pool_scenario(shards: u32, placement: PlacementPolicyKind) -> Config {
    let mut cfg = cloud_scenario(RegionPolicyKind::FlexibleShape);
    cfg.pool.shards = shards;
    cfg.pool.placement = placement;
    cfg
}

/// Streaming-pipeline scenario: the cloud driver with two tenants
/// submitting the three-stage camera → demosaic → Harris chain
/// ([`crate::tasks::AppId::Pipeline`], explicit inter-stage frame
/// streams) next to a camera and a Harris tenant, at rates that keep a
/// backlog of stream-heavy stages contending for corridor bandwidth.
/// The `[noc]` subsystem is on; `placement` is the ablation axis —
/// `CommAware` scores corridors and honors producer affinity,
/// `Oblivious` places first-fit while contention is still charged.
/// Arrivals are identical across the pair.
pub fn pipeline_scenario(placement: NocPlacementKind) -> Config {
    let mut cfg = cloud_scenario(RegionPolicyKind::FlexibleShape);
    cfg.noc.enabled = true;
    cfg.noc.placement = placement;
    if let WorkloadConfig::Cloud(ref mut c) = cfg.workload {
        c.tenant_apps = Some([AppId::Pipeline, AppId::Camera, AppId::Pipeline, AppId::Harris]);
        c.mean_interarrival_ms = [12.0, 12.0, 12.0, 14.0];
        c.duration_ms = 2_000.0;
        c.seed = 0x0C_07_2026;
    }
    cfg
}

/// Churn scenario (past-saturation Fig. 3a tenants, cost-aware defrag)
/// with the `[noc]` subsystem armed — the guard arm of
/// `benches/ablation_noc.rs`: comm-aware placement must not regress the
/// migration-heavy workload the defragmenter was built for.
pub fn noc_churn_scenario(placement: NocPlacementKind) -> Config {
    let mut cfg = churn_scenario(RegionPolicyKind::FlexibleShape, DefragPolicyKind::CostAware);
    cfg.noc.enabled = true;
    cfg.noc.placement = placement;
    cfg
}

/// Cloud scenario with the energy model live: accounting + power
/// gating on (Amber-derived `[energy]` defaults), flexible-shape
/// regions.  `power_cap_watts` stays 0 (uncapped) — pass the cap
/// explicitly where the governor is under test.
pub fn energy_scenario() -> Config {
    let mut cfg = cloud_scenario(RegionPolicyKind::FlexibleShape);
    cfg.energy.enabled = true;
    cfg
}

/// Churn scenario (past-saturation cloud load) with energy accounting
/// on and the power-cap governor armed at `cap_watts` (0 = uncapped) —
/// the `BENCH_energy.json` cap sweep.  Defrag stays off so the cap run
/// isolates the governor from migration effects.
pub fn energy_cap_scenario(cap_watts: f64) -> Config {
    let mut cfg = churn_scenario(RegionPolicyKind::FlexibleShape, DefragPolicyKind::Off);
    cfg.energy.enabled = true;
    cfg.energy.power_cap_watts = cap_watts;
    cfg
}

/// A sharded pool with energy accounting on — the arena where
/// `energy-aware` placement (consolidate, let drained shards deep-
/// sleep) is compared against `least-loaded` (spread, keep every
/// fabric awake).  The datacenter-shard static overhead is set above
/// the tile-level default: a deployed fabric shard carries host
/// interface, clocking and DDR PHY overheads that dwarf a lone
/// fabric's clock tree.
pub fn energy_pool_scenario(shards: u32, placement: PlacementPolicyKind) -> Config {
    let mut cfg = pool_scenario(shards, placement);
    cfg.energy.enabled = true;
    cfg.energy.fabric_static_pj = 2_000.0;
    cfg
}

/// Mixed-criticality preset: the paper's two workload families on one
/// fabric ([`crate::qos`]).  The autonomous tenants — camera (2) and
/// Harris (3) — run **Critical** with frame-scale deadlines, while the
/// cloud-multitenant tenants — ResNet-18 (0) and MobileNet (1) — run
/// **BestEffort** with no deadline, at the churn preset's
/// past-saturation offered load so priorities actually matter.
///
/// `preemptive = true` arms the QoS subsystem's EDF ordering and
/// checkpointed eviction; `false` keeps classes and deadlines *tracked*
/// (for SLO reporting) but schedules strictly FIFO — the
/// `benches/ablation_qos.rs` baseline at identical offered load.
pub fn mixed_criticality_scenario(preemptive: bool) -> Config {
    let mut cfg = cloud_scenario(RegionPolicyKind::FlexibleShape);
    cfg.qos.enabled = true;
    cfg.qos.policy = if preemptive { QosPolicyKind::Edf } else { QosPolicyKind::Fifo };
    cfg.qos.preemption = preemptive;
    cfg.qos.tenant_class = [
        QosClass::BestEffort,
        QosClass::BestEffort,
        QosClass::Critical,
        QosClass::Critical,
    ];
    // camera ≈ 1.4 ms of execution, Harris ≈ 0.5–1 ms: a 5/4 ms budget
    // is comfortable for a prioritized schedule and hopeless for a FIFO
    // one at this backlog.
    cfg.qos.deadline_ms = [0.0, 0.0, 5.0, 4.0];
    if let WorkloadConfig::Cloud(ref mut c) = cfg.workload {
        c.mean_interarrival_ms = [18.0, 10.0, 14.0, 11.0];
        c.duration_ms = 2_000.0;
        c.seed = 0xC6_05_2026;
    }
    cfg
}

/// Ablation: array-slice width (4/8/16 columns, DESIGN.md §6.1).
///
/// Widths must contain whole MEM-column periods (multiples of 4) or the
/// slices are not homogeneous and relocation would be unsound.
pub fn slice_width_ablation(slice_cols: u32) -> Config {
    let mut cfg = Config::default();
    cfg.arch.slice_cols = slice_cols;
    cfg
}

/// Ablation: scheduler policy (DESIGN.md §6.3).
pub fn scheduler_ablation(policy: SchedulerPolicyKind) -> Config {
    let mut cfg = Config::default();
    cfg.scheduler.policy = policy;
    cfg
}

/// Ablation: fast-DPR without bitstream relocation (DESIGN.md §6.4).
pub fn no_relocation() -> Config {
    let mut cfg = Config::default();
    cfg.dpr.relocation = false;
    cfg
}

/// A reduced geometry for fast unit tests (4 slices, 8 banks).
pub fn test_small() -> Config {
    let mut cfg = Config::default();
    cfg.arch.cols = 16;
    cfg.arch.rows = 8;
    cfg.arch.glb_banks = 8;
    cfg
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_presets_validate() {
        paper_default().validate().unwrap();
        for kind in RegionPolicyKind::ALL {
            cloud_scenario(kind).validate().unwrap();
            edge_scenario(kind).validate().unwrap();
            for defrag in DefragPolicyKind::ALL {
                churn_scenario(kind, defrag).validate().unwrap();
                edge_churn_scenario(kind, defrag).validate().unwrap();
            }
        }
        for w in [4, 8, 16] {
            slice_width_ablation(w).validate().unwrap();
        }
        for shards in [1, 2, 4] {
            for placement in PlacementPolicyKind::ALL {
                pool_scenario(shards, placement).validate().unwrap();
            }
        }
        scheduler_ablation(SchedulerPolicyKind::FcfsFirstFit).validate().unwrap();
        no_relocation().validate().unwrap();
        test_small().validate().unwrap();
        energy_scenario().validate().unwrap();
        energy_cap_scenario(2.5).validate().unwrap();
        energy_cap_scenario(0.0).validate().unwrap();
        mixed_criticality_scenario(true).validate().unwrap();
        mixed_criticality_scenario(false).validate().unwrap();
        for placement in PlacementPolicyKind::ALL {
            energy_pool_scenario(4, placement).validate().unwrap();
        }
        for placement in [NocPlacementKind::CommAware, NocPlacementKind::Oblivious] {
            pipeline_scenario(placement).validate().unwrap();
            noc_churn_scenario(placement).validate().unwrap();
        }
    }

    #[test]
    fn energy_presets_arm_the_model() {
        assert!(energy_scenario().energy.enabled);
        assert!(energy_scenario().energy.gating);
        assert_eq!(energy_scenario().energy.power_cap_watts, 0.0);
        let capped = energy_cap_scenario(2.5);
        assert!(capped.energy.enabled);
        assert_eq!(capped.energy.power_cap_watts, 2.5);
        assert_eq!(capped.scheduler.defrag_policy, DefragPolicyKind::Off);
        let pool = energy_pool_scenario(4, PlacementPolicyKind::EnergyAware);
        assert_eq!(pool.pool.shards, 4);
        assert!(pool.energy.fabric_static_pj > pool.energy.fabric_sleep_pj);
    }

    #[test]
    fn mixed_criticality_preset_arms_qos() {
        let edf = mixed_criticality_scenario(true);
        assert!(edf.qos.enabled);
        assert_eq!(edf.qos.policy, QosPolicyKind::Edf);
        assert!(edf.qos.preemption);
        assert_eq!(edf.qos.tenant_class[2], QosClass::Critical);
        assert_eq!(edf.qos.tenant_class[0], QosClass::BestEffort);
        assert!(edf.qos.deadline_ms[2] > 0.0);
        assert_eq!(edf.qos.deadline_ms[0], 0.0);
        let fifo = mixed_criticality_scenario(false);
        assert_eq!(fifo.qos.policy, QosPolicyKind::Fifo);
        assert!(!fifo.qos.preemption);
        // equal offered load across the ablation pair
        let (WorkloadConfig::Cloud(a), WorkloadConfig::Cloud(b)) =
            (&edf.workload, &fifo.workload)
        else {
            panic!("cloud workloads expected");
        };
        assert_eq!(a.mean_interarrival_ms, b.mean_interarrival_ms);
        assert_eq!(a.seed, b.seed);
    }

    #[test]
    fn pipeline_presets_arm_the_noc() {
        let aware = pipeline_scenario(NocPlacementKind::CommAware);
        assert!(aware.noc.enabled);
        assert_eq!(aware.noc.placement, NocPlacementKind::CommAware);
        let obliv = pipeline_scenario(NocPlacementKind::Oblivious);
        assert_eq!(obliv.noc.placement, NocPlacementKind::Oblivious);
        // equal offered load across the ablation pair, pipeline tenants on
        let (WorkloadConfig::Cloud(a), WorkloadConfig::Cloud(b)) =
            (&aware.workload, &obliv.workload)
        else {
            panic!("cloud workloads expected");
        };
        assert_eq!(a.tenant_apps.unwrap()[0], AppId::Pipeline);
        assert_eq!(a.tenant_apps, b.tenant_apps);
        assert_eq!(a.mean_interarrival_ms, b.mean_interarrival_ms);
        assert_eq!(a.seed, b.seed);
        let churn = noc_churn_scenario(NocPlacementKind::CommAware);
        assert!(churn.noc.enabled);
        assert_eq!(churn.scheduler.defrag_policy, DefragPolicyKind::CostAware);
    }

    #[test]
    fn slice_width_changes_slice_count() {
        assert_eq!(slice_width_ablation(4).arch.array_slices(), 8);
        assert_eq!(slice_width_ablation(8).arch.array_slices(), 4);
        assert_eq!(slice_width_ablation(16).arch.array_slices(), 2);
    }

    #[test]
    fn test_small_is_smaller() {
        let cfg = test_small();
        assert_eq!(cfg.arch.array_slices(), 4);
        assert_eq!(cfg.arch.glb_slices(), 8);
    }
}
