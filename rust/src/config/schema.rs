//! Typed configuration schema, populated from the TOML-subset parser.

use std::path::Path;

use crate::config::toml::TomlValue;
use crate::error::{Error, Result};
use crate::tasks::AppId;

/// CGRA architecture parameters (paper §2.1, Amber-like defaults).
#[derive(Clone, Debug, PartialEq)]
pub struct ArchConfig {
    /// Tile-array columns (paper: 32).
    pub cols: u32,
    /// Tile-array rows (paper: 16).
    pub rows: u32,
    /// Every `mem_col_period`-th column holds MEM tiles (paper: 4 ⇒
    /// 384 PE + 128 MEM tiles).
    pub mem_col_period: u32,
    /// GLB bank count (paper: 32).
    pub glb_banks: u32,
    /// SRAM capacity per GLB bank in KiB (paper: 128).
    pub glb_bank_kib: u32,
    /// Peak GLB bandwidth per bank, bytes/cycle (Amber: 8 B/cycle stream).
    pub glb_bank_bytes_per_cycle: u32,
    /// Core clock in MHz (paper quotes throughput at 500 MHz).
    pub core_clock_mhz: u32,
    /// AXI4-Lite configuration bus clock in MHz (baseline DPR path).
    pub axi_clock_mhz: u32,
    /// Routing tracks per direction in the mesh (paper: 5).
    pub tracks_per_dir: u32,
    /// Columns per array-slice (paper: 4 ⇒ 48 PE + 16 MEM per slice).
    pub slice_cols: u32,
}

impl ArchConfig {
    /// Total number of array-slices.
    pub fn array_slices(&self) -> u32 {
        self.cols / self.slice_cols
    }

    /// Total number of GLB-slices (one per bank, paper §2.2).
    pub fn glb_slices(&self) -> u32 {
        self.glb_banks
    }

    /// MEM-tile columns in the whole array.
    pub fn mem_cols(&self) -> u32 {
        self.cols / self.mem_col_period
    }

    /// PE tiles in the whole array.
    pub fn pe_tiles(&self) -> u32 {
        (self.cols - self.mem_cols()) * self.rows
    }

    /// MEM tiles in the whole array.
    pub fn mem_tiles(&self) -> u32 {
        self.mem_cols() * self.rows
    }

    /// PE tiles per array-slice.
    pub fn pe_tiles_per_slice(&self) -> u32 {
        self.pe_tiles() / self.array_slices()
    }

    /// MEM tiles per array-slice.
    pub fn mem_tiles_per_slice(&self) -> u32 {
        self.mem_tiles() / self.array_slices()
    }

    /// GLB capacity per slice in bytes.
    pub fn glb_slice_bytes(&self) -> u64 {
        self.glb_bank_kib as u64 * 1024
    }

    /// GLB bandwidth per slice in bytes/second.
    pub fn glb_slice_bw_bytes_per_sec(&self) -> f64 {
        self.glb_bank_bytes_per_cycle as f64 * self.core_clock_mhz as f64 * 1e6
    }

    /// Validate internal consistency.
    pub fn validate(&self) -> Result<()> {
        let err = |m: String| Err(Error::Config(m));
        if self.cols == 0 || self.rows == 0 {
            return err("array dimensions must be positive".into());
        }
        if self.slice_cols == 0 || self.cols % self.slice_cols != 0 {
            return err(format!(
                "cols ({}) must be a positive multiple of slice_cols ({})",
                self.cols, self.slice_cols
            ));
        }
        if self.mem_col_period == 0 || self.cols % self.mem_col_period != 0 {
            return err(format!(
                "cols ({}) must be a multiple of mem_col_period ({})",
                self.cols, self.mem_col_period
            ));
        }
        if self.slice_cols % self.mem_col_period != 0 {
            return err(format!(
                "slice_cols ({}) must contain whole MEM periods ({}) so slices are homogeneous",
                self.slice_cols, self.mem_col_period
            ));
        }
        if self.glb_banks == 0 || self.glb_banks % self.array_slices() != 0 {
            return err(format!(
                "glb_banks ({}) must be a multiple of the array-slice count ({})",
                self.glb_banks,
                self.array_slices()
            ));
        }
        if self.core_clock_mhz == 0 || self.axi_clock_mhz == 0 {
            return err("clocks must be positive".into());
        }
        Ok(())
    }
}

impl Default for ArchConfig {
    /// Paper-faithful Amber-like geometry.
    fn default() -> Self {
        ArchConfig {
            cols: 32,
            rows: 16,
            mem_col_period: 4,
            glb_banks: 32,
            glb_bank_kib: 128,
            glb_bank_bytes_per_cycle: 8,
            core_clock_mhz: 500,
            axi_clock_mhz: 100,
            tracks_per_dir: 5,
            slice_cols: 4,
        }
    }
}

/// DPR engine parameters (paper §2.3 "Dynamic Partial Reconfiguration").
#[derive(Clone, Debug, PartialEq)]
pub struct DprConfig {
    /// AXI4-Lite data width in bits (baseline DPR).
    pub axi_word_bits: u32,
    /// Bus cycles per AXI4-Lite write (address + data phases).
    pub axi_cycles_per_word: u32,
    /// fast-DPR stream width in bits per cycle per GLB bank (Amber: 64).
    pub fast_word_bits: u32,
    /// Config words (32-bit) per PE tile.
    pub pe_config_words: u32,
    /// Config words per MEM tile.
    pub mem_config_words: u32,
    /// Config words per tile for interconnect (switch + connection boxes).
    pub route_config_words: u32,
    /// Whether region-agnostic bitstream relocation is available (paper's
    /// addition over Amber; turning it off is the §6.4 ablation).
    pub relocation: bool,
}

impl Default for DprConfig {
    fn default() -> Self {
        DprConfig {
            axi_word_bits: 32,
            axi_cycles_per_word: 2,
            fast_word_bits: 64,
            pe_config_words: 64,
            mem_config_words: 96,
            route_config_words: 32,
            relocation: true,
        }
    }
}

impl DprConfig {
    /// Validate internal consistency.
    pub fn validate(&self) -> Result<()> {
        if self.axi_word_bits == 0
            || self.axi_cycles_per_word == 0
            || self.fast_word_bits == 0
        {
            return Err(Error::Config("DPR widths/cycles must be positive".into()));
        }
        Ok(())
    }
}

/// Per-component energy/power model parameters (`[energy]` in TOML).
///
/// All per-cycle costs are in **picojoules per cycle** at the core
/// clock; `1 pJ/cycle = 0.5 mW` at the default 500 MHz.  The defaults
/// are an Amber-derived preset: a 16 nm CGRA with ~512 tiles and 32
/// GLB banks lands in the low single-digit-watt range when fully
/// active, with idle leakage about a tenth of active power and
/// power-gated domains two orders of magnitude below idle.
///
/// `enabled = false` (the default) keeps every existing report, trace
/// and golden-equivalence property bit-for-bit unchanged: no energy is
/// accounted, no slice is gated, and no wake latency is charged.
#[derive(Clone, Debug, PartialEq)]
pub struct EnergyConfig {
    /// Master switch for energy accounting + power gating.
    /// TOML: `energy.enabled`.
    pub enabled: bool,
    /// Power-gate unallocated slices (see `gate_min_run`).  Only
    /// effective when `enabled`.  TOML: `energy.gating`.
    pub gating: bool,
    /// Minimum *contiguous free run* (slices) a power domain needs
    /// before it can be gated: scattered holes shorter than this stay
    /// awake at idle power — external fragmentation costs watts, and
    /// defragmentation earns them back.  TOML: `energy.gate_min_run`.
    pub gate_min_run: u32,
    /// Wake latency of a gated domain, charged to the waking launch
    /// like DPR cycles.  TOML: `energy.wake_cycles`.
    pub wake_cycles: u64,
    /// PE tile, computing.  TOML: `energy.pe_active_pj`.
    pub pe_active_pj: f64,
    /// PE tile, allocated-or-awake but not clocked into a region.
    pub pe_idle_pj: f64,
    /// MEM tile, computing (SRAM active).
    pub mem_active_pj: f64,
    /// MEM tile, idle.
    pub mem_idle_pj: f64,
    /// Any tile inside a power-gated domain (leakage floor).
    pub tile_gated_pj: f64,
    /// GLB bank held by a region (SRAM retention + clocking).
    pub glb_active_pj: f64,
    /// GLB bank awake but unallocated.
    pub glb_idle_pj: f64,
    /// GLB bank power-gated.
    pub glb_gated_pj: f64,
    /// Stream-port switching energy per byte moved (task streaming,
    /// fast-DPR, migration bank copies).
    pub glb_stream_pj_per_byte: f64,
    /// Fraction of the peak per-bank port bandwidth an *active* bank is
    /// assumed to stream (Table 1 rows carry slice counts, not raw
    /// bandwidth; [`crate::abstraction::RawUsage`]-derived demands use
    /// the measured bandwidth instead).  TOML: `energy.stream_duty`.
    pub stream_duty: f64,
    /// Configuration-stream energy per bit (fast-DPR and AXI alike).
    pub dpr_pj_per_bit: f64,
    /// Always-on fabric overhead while the fabric hosts ≥ 1 region
    /// (clock tree, host interface).  TOML: `energy.fabric_static_pj`.
    pub fabric_static_pj: f64,
    /// Fabric overhead when fully drained (deep sleep) — what an
    /// energy-aware pool placement earns by consolidating onto fewer
    /// shards.  TOML: `energy.fabric_sleep_pj`.
    pub fabric_sleep_pj: f64,
    /// Power cap for the governor, watts; `0` disables the cap.
    /// TOML: `energy.power_cap_watts`.
    pub power_cap_watts: f64,
    /// Averaging window (cycles) for the governor's windowed power.
    /// TOML: `energy.power_window_cycles`.
    pub power_window_cycles: u64,
}

impl Default for EnergyConfig {
    fn default() -> Self {
        EnergyConfig {
            enabled: false,
            gating: true,
            gate_min_run: 4,
            wake_cycles: 96,
            pe_active_pj: 8.0,
            pe_idle_pj: 0.8,
            mem_active_pj: 12.0,
            mem_idle_pj: 1.2,
            tile_gated_pj: 0.02,
            glb_active_pj: 20.0,
            glb_idle_pj: 2.0,
            glb_gated_pj: 0.05,
            glb_stream_pj_per_byte: 1.5,
            stream_duty: 0.6,
            dpr_pj_per_bit: 0.15,
            fabric_static_pj: 500.0,
            fabric_sleep_pj: 5.0,
            power_cap_watts: 0.0,
            power_window_cycles: 50_000,
        }
    }
}

impl EnergyConfig {
    /// Validate internal consistency.
    pub fn validate(&self) -> Result<()> {
        let costs = [
            self.pe_active_pj,
            self.pe_idle_pj,
            self.mem_active_pj,
            self.mem_idle_pj,
            self.tile_gated_pj,
            self.glb_active_pj,
            self.glb_idle_pj,
            self.glb_gated_pj,
            self.glb_stream_pj_per_byte,
            self.dpr_pj_per_bit,
            self.fabric_static_pj,
            self.fabric_sleep_pj,
            self.power_cap_watts,
        ];
        if costs.iter().any(|c| !c.is_finite() || *c < 0.0) {
            return Err(Error::Config(
                "energy costs must be finite and non-negative".into(),
            ));
        }
        if !(0.0..=1.0).contains(&self.stream_duty) {
            return Err(Error::Config(format!(
                "energy.stream_duty ({}) must be within [0, 1]",
                self.stream_duty
            )));
        }
        if self.gate_min_run == 0 {
            return Err(Error::Config("energy.gate_min_run must be positive".into()));
        }
        if self.power_window_cycles == 0 {
            return Err(Error::Config("energy.power_window_cycles must be positive".into()));
        }
        Ok(())
    }
}

/// QoS priority class of a request ([`crate::qos`]).
///
/// Ordered so that `BestEffort < Interactive < Critical`: the scheduler
/// compares classes directly, and preemption is only ever allowed in
/// the strictly-ascending direction (a higher class evicts a lower one,
/// never the reverse).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum QosClass {
    /// Throughput-oriented background work; may be preempted and aged.
    BestEffort,
    /// Latency-sensitive but not safety-critical.
    Interactive,
    /// Hard latency budget (the autonomous workload); never preempted
    /// by a lower class.
    Critical,
}

impl QosClass {
    /// All classes, lowest priority first.
    pub const ALL: [QosClass; 3] =
        [QosClass::BestEffort, QosClass::Interactive, QosClass::Critical];

    /// Stable config / display name.
    pub fn name(&self) -> &'static str {
        match self {
            QosClass::BestEffort => "best-effort",
            QosClass::Interactive => "interactive",
            QosClass::Critical => "critical",
        }
    }

    /// Parse a config / wire name.
    pub fn from_name(name: &str) -> Result<Self> {
        match name {
            "best-effort" | "best_effort" | "besteffort" => Ok(QosClass::BestEffort),
            "interactive" => Ok(QosClass::Interactive),
            "critical" => Ok(QosClass::Critical),
            other => Err(Error::Config(format!("unknown QoS class '{other}'"))),
        }
    }

    /// Index into per-class arrays (`BestEffort` = 0 … `Critical` = 2).
    pub fn index(&self) -> usize {
        match self {
            QosClass::BestEffort => 0,
            QosClass::Interactive => 1,
            QosClass::Critical => 2,
        }
    }
}

/// How the QoS scheduler orders the ready frontier ([`crate::qos`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum QosPolicyKind {
    /// Arrival order — classes and deadlines are tracked for SLO
    /// reporting but do not influence scheduling (the ablation
    /// baseline).
    Fifo,
    /// Strict priority across classes, earliest-deadline-first within a
    /// class, with BestEffort aging (`qos.aging_cycles`).
    Edf,
}

impl QosPolicyKind {
    /// Stable config / display name.
    pub fn name(&self) -> &'static str {
        match self {
            QosPolicyKind::Fifo => "fifo",
            QosPolicyKind::Edf => "edf",
        }
    }

    /// Parse a config name.
    pub fn from_name(name: &str) -> Result<Self> {
        match name {
            "fifo" => Ok(QosPolicyKind::Fifo),
            "edf" => Ok(QosPolicyKind::Edf),
            other => Err(Error::Config(format!("unknown QoS policy '{other}'"))),
        }
    }
}

/// QoS subsystem configuration (`[qos]` in TOML; [`crate::qos`]).
///
/// `enabled = false` (the default) is the master switch: no class
/// ordering, no preemption, no SLO tracking — every existing preset,
/// trace and report stays bit-for-bit unchanged (`tests/determinism.rs`
/// holds the subsystem to that).
#[derive(Clone, Debug, PartialEq)]
pub struct QosConfig {
    /// Master switch.  TOML: `qos.enabled`.
    pub enabled: bool,
    /// Ready-frontier ordering.  TOML: `qos.policy` = "fifo" | "edf".
    pub policy: QosPolicyKind,
    /// Allow a blocked higher-class task to checkpoint-and-evict
    /// running lower-class tasks.  Only effective under `policy =
    /// "edf"` — the FIFO baseline never evicts regardless of this
    /// knob.  TOML: `qos.preemption`.
    pub preemption: bool,
    /// Starvation guard: a BestEffort task that has waited at least
    /// this many cycles is ordered as Interactive (it still never
    /// preempts anyone).  0 disables aging.  TOML: `qos.aging_cycles`.
    pub aging_cycles: u64,
    /// Cap on victims evicted per preemption pass.
    /// TOML: `qos.max_victims`.
    pub max_victims: u32,
    /// Default class per tenant (the sims and the wire SUBMIT default
    /// when no explicit class is given).  TOML: `qos.tenant_classes`,
    /// an array of 4 class names.
    pub tenant_class: [QosClass; 4],
    /// Relative deadline per tenant in milliseconds from arrival;
    /// 0 = no deadline.  TOML: `qos.deadline_ms`, an array of 4.
    pub deadline_ms: [f64; 4],
}

impl Default for QosConfig {
    fn default() -> Self {
        QosConfig {
            enabled: false,
            policy: QosPolicyKind::Edf,
            preemption: true,
            // 10 ms at the 500 MHz core clock: long enough that genuine
            // latency-class work always goes first, short enough that
            // BestEffort cannot starve across even one camera frame.
            aging_cycles: 5_000_000,
            max_victims: 4,
            tenant_class: [QosClass::BestEffort; 4],
            deadline_ms: [0.0; 4],
        }
    }
}

impl QosConfig {
    /// Validate internal consistency.
    pub fn validate(&self) -> Result<()> {
        if self.max_victims == 0 {
            return Err(Error::Config("qos.max_victims must be positive".into()));
        }
        if self.deadline_ms.iter().any(|d| !d.is_finite() || *d < 0.0) {
            return Err(Error::Config(
                "qos.deadline_ms entries must be finite and non-negative".into(),
            ));
        }
        Ok(())
    }

    /// Default class for a tenant's requests (BestEffort when the
    /// subsystem is disabled).
    pub fn class_of_tenant(&self, tenant: u32) -> QosClass {
        if !self.enabled {
            return QosClass::BestEffort;
        }
        self.tenant_class[tenant as usize % 4]
    }

    /// Absolute deadline for a tenant's request arriving at
    /// `arrival_cycle` (`None` when disabled or no budget configured).
    pub fn deadline_of_tenant(&self, tenant: u32, arrival_cycle: u64, cycles_per_ms: u64) -> Option<u64> {
        if !self.enabled {
            return None;
        }
        let ms = self.deadline_ms[tenant as usize % 4];
        if ms <= 0.0 {
            return None;
        }
        Some(arrival_cycle + (ms * cycles_per_ms as f64) as u64)
    }
}

/// How placement treats corridor bandwidth when the NoC model is on.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum NocPlacementKind {
    /// Score candidate runs by projected corridor oversubscription and
    /// honor producer-affinity hints from the app DAG.
    CommAware,
    /// Ignore corridors when placing (first-fit, as before); contention
    /// is still charged — this is the ablation baseline.
    Oblivious,
}

impl NocPlacementKind {
    /// Stable config / display name.
    pub fn name(&self) -> &'static str {
        match self {
            NocPlacementKind::CommAware => "comm-aware",
            NocPlacementKind::Oblivious => "oblivious",
        }
    }

    /// Parse a config name.
    pub fn from_name(name: &str) -> Result<Self> {
        match name {
            "comm-aware" => Ok(NocPlacementKind::CommAware),
            "oblivious" => Ok(NocPlacementKind::Oblivious),
            other => Err(Error::Config(format!("unknown NoC placement '{other}'"))),
        }
    }
}

/// NoC bandwidth-provisioning configuration (`[noc]` in TOML;
/// [`crate::noc`]).
///
/// `enabled = false` (the default) is the master switch: no corridor is
/// tracked, no stream is charged, no placement decision changes —
/// every existing preset, trace and report stays bit-for-bit unchanged
/// (`tests/prop_noc.rs` holds the subsystem to that, same discipline as
/// `[energy]` and `[qos]`).
#[derive(Clone, Debug, PartialEq)]
pub struct NocConfig {
    /// Master switch.  TOML: `noc.enabled`.
    pub enabled: bool,
    /// Placement flavor.  TOML: `noc.placement` =
    /// "comm-aware" | "oblivious".
    pub placement: NocPlacementKind,
    /// Fraction of a task's execution that is stream-bandwidth-bound
    /// (stretched by corridor oversubscription).  TOML:
    /// `noc.comm_fraction`, within [0, 1].
    pub comm_fraction: f64,
    /// Use app-DAG producer positions as placement hints so consumer
    /// stages land on the corridors their input already lives in.
    /// TOML: `noc.stream_affinity`.
    pub stream_affinity: bool,
    /// Make the defragmenter's packing order follow GLB home columns
    /// (narrowing corridor spans) instead of pure array order.
    /// TOML: `noc.defrag_align`.
    pub defrag_align: bool,
}

impl Default for NocConfig {
    fn default() -> Self {
        NocConfig {
            enabled: false,
            placement: NocPlacementKind::CommAware,
            // Table 1 tasks stream operands continuously but re-use
            // tiles heavily; ~a third of the steady-state cycles are
            // bandwidth-bound at the 8 B/cycle bank rate.
            comm_fraction: 0.35,
            stream_affinity: true,
            defrag_align: true,
        }
    }
}

impl NocConfig {
    /// Validate internal consistency.
    pub fn validate(&self) -> Result<()> {
        if !(0.0..=1.0).contains(&self.comm_fraction) || !self.comm_fraction.is_finite() {
            return Err(Error::Config(format!(
                "noc.comm_fraction ({}) must be within [0, 1]",
                self.comm_fraction
            )));
        }
        Ok(())
    }
}

/// `[obs]` — end-to-end observability ([`crate::obs`]): the typed
/// metrics registry, the request-scoped lifecycle journal and its
/// exporters (the `METRICS` wire command, Perfetto JSON).
///
/// Off by default with a hard byte-identity requirement: disabled
/// observability must not change any sim or serving output (the
/// differential goldens enforce this), same discipline as `[energy]`,
/// `[qos]` and `[noc]`.
#[derive(Clone, Debug, PartialEq)]
pub struct ObsConfig {
    /// Master switch.  TOML: `obs.enabled`.
    pub enabled: bool,
    /// Lifecycle-journal capacity in events; the journal is a ring, so
    /// the newest `journal_cap` events are retained.  TOML:
    /// `obs.journal_cap`.
    pub journal_cap: usize,
    /// Decision-provenance switch (layer 2): record *why* the
    /// scheduler chose a variant/shard/victim/defrag plan, queryable
    /// over the wire with `EXPLAIN <req_id>`.  Requires `enabled`.
    /// TOML: `obs.provenance`.
    pub provenance: bool,
    /// Provenance-ring capacity in decision records (ring semantics,
    /// like the journal).  TOML: `obs.provenance_cap`.
    pub provenance_cap: usize,
    /// SLO burn-rate watchdog switch: multi-window burn rates over the
    /// per-class SLO stream plus per-shard utilization/power anomaly
    /// scoring, raising typed alerts into the registry and journal.
    /// Requires `enabled`.  TOML: `obs.watchdog`.
    pub watchdog: bool,
    /// Fast burn-rate window, in deadlined completions per class.
    /// TOML: `obs.slo_fast_window`.
    pub slo_fast_window: usize,
    /// Slow burn-rate window, in deadlined completions per class.
    /// TOML: `obs.slo_slow_window`.
    pub slo_slow_window: usize,
    /// SLO error budget: the tolerated deadline-miss fraction a burn
    /// rate of 1.0 corresponds to.  TOML: `obs.slo_budget`.
    pub slo_budget: f64,
    /// Fast-window burn-rate alert threshold (multiples of budget).
    /// TOML: `obs.burn_fast`.
    pub burn_fast: f64,
    /// Slow-window burn-rate alert threshold (multiples of budget);
    /// both windows must burn above threshold to fire (the classic
    /// multi-window guard against blips and stale alerts).  TOML:
    /// `obs.burn_slow`.
    pub burn_slow: f64,
    /// Per-shard anomaly threshold in standard deviations: a
    /// utilization or power sample further than this from the shard's
    /// running mean raises an anomaly alert.  TOML:
    /// `obs.anomaly_sigma`.
    pub anomaly_sigma: f64,
    /// Per-subscriber `WATCH` queue capacity in events: a subscriber
    /// falling further behind than this has events dropped-and-counted
    /// rather than blocking the serving front.  TOML:
    /// `obs.watch_queue_cap`.
    pub watch_queue_cap: usize,
}

impl Default for ObsConfig {
    fn default() -> Self {
        ObsConfig {
            enabled: false,
            journal_cap: 65_536,
            provenance: false,
            provenance_cap: 4096,
            watchdog: false,
            slo_fast_window: 32,
            slo_slow_window: 256,
            slo_budget: 0.01,
            burn_fast: 8.0,
            burn_slow: 2.0,
            anomaly_sigma: 4.0,
            watch_queue_cap: 1024,
        }
    }
}

impl ObsConfig {
    /// Validate internal consistency.
    pub fn validate(&self) -> Result<()> {
        if self.enabled && self.journal_cap == 0 {
            return Err(Error::Config(
                "obs.journal_cap must be positive when obs.enabled".into(),
            ));
        }
        if (self.provenance || self.watchdog) && !self.enabled {
            return Err(Error::Config(
                "obs.provenance / obs.watchdog require obs.enabled".into(),
            ));
        }
        if self.provenance && self.provenance_cap == 0 {
            return Err(Error::Config(
                "obs.provenance_cap must be positive when obs.provenance".into(),
            ));
        }
        if self.watchdog {
            if self.slo_fast_window == 0 || self.slo_slow_window < self.slo_fast_window {
                return Err(Error::Config(
                    "obs watchdog windows need 0 < slo_fast_window <= slo_slow_window".into(),
                ));
            }
            if !(self.slo_budget > 0.0 && self.slo_budget <= 1.0) {
                return Err(Error::Config(format!(
                    "obs.slo_budget ({}) must be within (0, 1]",
                    self.slo_budget
                )));
            }
            if self.burn_fast <= 0.0 || self.burn_slow <= 0.0 || self.anomaly_sigma <= 0.0 {
                return Err(Error::Config(
                    "obs.burn_fast / obs.burn_slow / obs.anomaly_sigma must be positive".into(),
                ));
            }
        }
        if self.enabled && self.watch_queue_cap == 0 {
            return Err(Error::Config(
                "obs.watch_queue_cap must be positive when obs.enabled".into(),
            ));
        }
        Ok(())
    }
}

/// Execution-region formation mechanism (paper Fig. 2 a–d).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum RegionPolicyKind {
    /// Whole CGRA is one region; tasks run one at a time (Fig. 2a).
    Baseline,
    /// Fixed-size regions; unrolled tasks span several (Fig. 2b).
    FixedSize,
    /// Adjacent unit regions merge into larger ones (Fig. 2c).
    VariableSize,
    /// GLB-slices and array-slices decoupled (Fig. 2d, the contribution).
    FlexibleShape,
}

impl RegionPolicyKind {
    /// All mechanisms, in the paper's presentation order.
    pub const ALL: [RegionPolicyKind; 4] = [
        RegionPolicyKind::Baseline,
        RegionPolicyKind::FixedSize,
        RegionPolicyKind::VariableSize,
        RegionPolicyKind::FlexibleShape,
    ];

    /// Stable config / display name.
    pub fn name(&self) -> &'static str {
        match self {
            RegionPolicyKind::Baseline => "baseline",
            RegionPolicyKind::FixedSize => "fixed",
            RegionPolicyKind::VariableSize => "variable",
            RegionPolicyKind::FlexibleShape => "flexible",
        }
    }

    /// Parse a config name.
    pub fn from_name(name: &str) -> Result<Self> {
        match name {
            "baseline" => Ok(RegionPolicyKind::Baseline),
            "fixed" => Ok(RegionPolicyKind::FixedSize),
            "variable" => Ok(RegionPolicyKind::VariableSize),
            "flexible" => Ok(RegionPolicyKind::FlexibleShape),
            other => Err(Error::Config(format!("unknown region policy '{other}'"))),
        }
    }
}

/// Task-selection policy for the scheduler.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SchedulerPolicyKind {
    /// Paper's policy: among runnable variants, pick highest throughput.
    GreedyThroughput,
    /// First-fit in arrival order, lowest-throughput variant that fits.
    FcfsFirstFit,
    /// Round-robin across tenants, greedy variant choice within a tenant.
    FairShare,
    /// Shortest-job-first: ready tasks ordered by their minimum execution
    /// time (favors the short vision tasks whose NTAT is wait-dominated).
    ShortestJobFirst,
    /// Energy-aware: among runnable variants, pick the one minimizing
    /// the energy-delay product (active power × exec-time²) under the
    /// configured [`EnergyConfig`] model, instead of max throughput.
    EnergyAware,
}

impl SchedulerPolicyKind {
    /// Stable config / display name.
    pub fn name(&self) -> &'static str {
        match self {
            SchedulerPolicyKind::GreedyThroughput => "greedy",
            SchedulerPolicyKind::FcfsFirstFit => "fcfs",
            SchedulerPolicyKind::FairShare => "fair",
            SchedulerPolicyKind::ShortestJobFirst => "sjf",
            SchedulerPolicyKind::EnergyAware => "energy-aware",
        }
    }

    /// Parse a config name.
    pub fn from_name(name: &str) -> Result<Self> {
        match name {
            "greedy" => Ok(SchedulerPolicyKind::GreedyThroughput),
            "fcfs" => Ok(SchedulerPolicyKind::FcfsFirstFit),
            "fair" => Ok(SchedulerPolicyKind::FairShare),
            "sjf" => Ok(SchedulerPolicyKind::ShortestJobFirst),
            "energy-aware" | "energy_aware" => Ok(SchedulerPolicyKind::EnergyAware),
            other => Err(Error::Config(format!("unknown scheduler policy '{other}'"))),
        }
    }
}

/// When the scheduler may migrate running tasks to defragment the
/// slice maps ([`crate::migration`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum DefragPolicyKind {
    /// Never migrate (the pre-migration behavior; a `NoFit` just waits).
    Off,
    /// Commit every viable compaction plan, cost be damned.
    Greedy,
    /// Commit a plan only when its estimated cycle cost is repaid by the
    /// execution time of the backlogged task it unblocks.
    CostAware,
}

impl DefragPolicyKind {
    /// All policies, cheapest-first.
    pub const ALL: [DefragPolicyKind; 3] = [
        DefragPolicyKind::Off,
        DefragPolicyKind::Greedy,
        DefragPolicyKind::CostAware,
    ];

    /// Stable config / display name.
    pub fn name(&self) -> &'static str {
        match self {
            DefragPolicyKind::Off => "off",
            DefragPolicyKind::Greedy => "greedy",
            DefragPolicyKind::CostAware => "cost-aware",
        }
    }

    /// Parse a config name.
    pub fn from_name(name: &str) -> Result<Self> {
        match name {
            "off" => Ok(DefragPolicyKind::Off),
            "greedy" => Ok(DefragPolicyKind::Greedy),
            "cost-aware" | "cost_aware" => Ok(DefragPolicyKind::CostAware),
            other => Err(Error::Config(format!("unknown defrag policy '{other}'"))),
        }
    }
}

/// How migration cycle cost is estimated ([`crate::migration`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum MigrationCostModelKind {
    /// Migrations are free (idealized upper bound for ablations).
    Zero,
    /// Checkpoint + fast-DPR restream only (GLB data assumed to stay in
    /// place or be double-mapped).
    DprOnly,
    /// Checkpoint + fast-DPR restream + bank-to-bank GLB state copy —
    /// the honest model, and the default.
    Full,
}

impl MigrationCostModelKind {
    /// All models, cheapest-first.
    pub const ALL: [MigrationCostModelKind; 3] = [
        MigrationCostModelKind::Zero,
        MigrationCostModelKind::DprOnly,
        MigrationCostModelKind::Full,
    ];

    /// Stable config / display name.
    pub fn name(&self) -> &'static str {
        match self {
            MigrationCostModelKind::Zero => "zero",
            MigrationCostModelKind::DprOnly => "dpr-only",
            MigrationCostModelKind::Full => "full",
        }
    }

    /// Parse a config name.
    pub fn from_name(name: &str) -> Result<Self> {
        match name {
            "zero" => Ok(MigrationCostModelKind::Zero),
            "dpr-only" | "dpr_only" => Ok(MigrationCostModelKind::DprOnly),
            "full" => Ok(MigrationCostModelKind::Full),
            other => Err(Error::Config(format!("unknown migration cost model '{other}'"))),
        }
    }
}

/// How the fabric-pool router scores shards when placing a request
/// ([`crate::fabric::FabricRouter`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum PlacementPolicyKind {
    /// Fewest open requests, then fewest busy array slices, then lowest
    /// shard id — the latency-spreading default.
    LeastLoaded,
    /// Among shards whose geometry can ever host the request's minimal
    /// demand, the one with the tightest (smallest-capacity) shape;
    /// least-loaded breaks ties.  On a homogeneous pool this degenerates
    /// to least-loaded, but heterogeneous pools keep small tasks off the
    /// big shards (the arXiv 2412.08137 provisioning argument).
    BestFit,
    /// Tenant affinity: a tenant's first request is placed least-loaded,
    /// every later one lands on the same shard (bitstream caches and GLB
    /// working sets stay warm).
    Sticky,
    /// Route to the shard whose *marginal power* for hosting the request
    /// is smallest under the [`EnergyConfig`] model: an already-awake
    /// shard with idle slices beats waking gated domains, which beats
    /// waking a deep-sleeping fabric — requests consolidate so drained
    /// shards stay asleep.
    EnergyAware,
}

impl PlacementPolicyKind {
    /// All policies, in documentation order.
    pub const ALL: [PlacementPolicyKind; 4] = [
        PlacementPolicyKind::LeastLoaded,
        PlacementPolicyKind::BestFit,
        PlacementPolicyKind::Sticky,
        PlacementPolicyKind::EnergyAware,
    ];

    /// Stable config / display name.
    pub fn name(&self) -> &'static str {
        match self {
            PlacementPolicyKind::LeastLoaded => "least-loaded",
            PlacementPolicyKind::BestFit => "best-fit",
            PlacementPolicyKind::Sticky => "sticky",
            PlacementPolicyKind::EnergyAware => "energy-aware",
        }
    }

    /// Parse a config name.
    pub fn from_name(name: &str) -> Result<Self> {
        match name {
            "least-loaded" | "least_loaded" => Ok(PlacementPolicyKind::LeastLoaded),
            "best-fit" | "best_fit" => Ok(PlacementPolicyKind::BestFit),
            "sticky" | "affinity" => Ok(PlacementPolicyKind::Sticky),
            "energy-aware" | "energy_aware" => Ok(PlacementPolicyKind::EnergyAware),
            other => Err(Error::Config(format!("unknown placement policy '{other}'"))),
        }
    }
}

/// Fabric-pool (sharding) configuration (`[pool]` in TOML).
///
/// A pool of `shards` independent CGRA fabrics — each with its own
/// region manager, DPR engine and scheduler state — served by one
/// placement router ([`crate::fabric`]).  `shards = 1` is bit-for-bit
/// the single-fabric behavior every earlier PR shipped.
#[derive(Clone, Debug, PartialEq)]
pub struct PoolConfig {
    /// Number of independent fabric instances.  TOML: `pool.shards`.
    pub shards: u32,
    /// Shard-scoring policy for request placement.
    /// TOML: `pool.placement` = "least-loaded" | "best-fit" | "sticky".
    pub placement: PlacementPolicyKind,
    /// Per-shard cap on open (incomplete) requests in the pool sims and
    /// benches; an arrival that finds *every* shard at the cap is
    /// rejected `BUSY` instead of queued.  `0` disables the cap (the
    /// default — single-fabric sims have no admission bound either).
    /// TOML: `pool.admission_window`.
    pub admission_window: u32,
}

impl Default for PoolConfig {
    fn default() -> Self {
        PoolConfig {
            shards: 1,
            placement: PlacementPolicyKind::LeastLoaded,
            admission_window: 0,
        }
    }
}

impl PoolConfig {
    /// Validate internal consistency.
    pub fn validate(&self) -> Result<()> {
        if self.shards == 0 {
            return Err(Error::Config("pool.shards must be positive".into()));
        }
        if self.shards > 64 {
            return Err(Error::Config(format!(
                "pool.shards ({}) is unreasonably large (max 64)",
                self.shards
            )));
        }
        Ok(())
    }
}

/// Scheduler + region-mechanism configuration.
#[derive(Clone, Debug, PartialEq)]
pub struct SchedulerConfig {
    /// Region formation mechanism.
    pub region_policy: RegionPolicyKind,
    /// Task/variant selection policy.
    pub policy: SchedulerPolicyKind,
    /// Unit region size for fixed/variable mechanisms: GLB slices.
    pub unit_glb_slices: u32,
    /// Unit region size for fixed/variable mechanisms: array slices.
    pub unit_array_slices: u32,
    /// When true, the baseline mechanism runs each task's single
    /// standard mapping (variant `a`) instead of choosing among the
    /// pre-compiled variants.  The variant library is part of the
    /// proposed abstraction (§2.2), so an embedded baseline deployment
    /// (Fig. 5) has exactly one bitstream per task; the cloud comparison
    /// (Fig. 4) keeps the generous any-variant baseline so its margins
    /// are conservative.
    pub baseline_single_mapping: bool,
    /// Live-migration defragmentation policy ([`crate::migration`]).
    /// TOML: `scheduler.defrag_policy` = "off" | "greedy" | "cost-aware".
    pub defrag_policy: DefragPolicyKind,
    /// Minimum external fragmentation (either slice class, `[0,1]`)
    /// before the planner bothers proposing a compaction plan.
    /// TOML: `scheduler.defrag_threshold`.
    pub defrag_threshold: f64,
    /// Cycle-cost model charged per migrated task.
    /// TOML: `scheduler.migration_cost_model` = "zero" | "dpr-only" | "full".
    pub migration_cost_model: MigrationCostModelKind,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig {
            region_policy: RegionPolicyKind::FlexibleShape,
            policy: SchedulerPolicyKind::GreedyThroughput,
            // Unit region sized so the *typical* Table 1 variant-a task
            // fits ("the largest task … determines the size", §2.3):
            // (8 GLB, 2 array) ⇒ 4 units.  The conv5_x / camera outliers
            // fall back to exclusive execution under fixed-size.
            unit_glb_slices: 8,
            unit_array_slices: 2,
            baseline_single_mapping: false,
            defrag_policy: DefragPolicyKind::Off,
            defrag_threshold: 0.25,
            migration_cost_model: MigrationCostModelKind::Full,
        }
    }
}

/// Cloud scenario workload (paper §3.1, Fig. 3a).
#[derive(Clone, Debug, PartialEq)]
pub struct CloudWorkloadConfig {
    /// Mean request inter-arrival time per tenant, in milliseconds.
    pub mean_interarrival_ms: [f64; 4],
    /// Simulated wall-clock duration in milliseconds.
    pub duration_ms: f64,
    /// RNG seed.
    pub seed: u64,
    /// Override which app each tenant submits.  `None` (the default)
    /// keeps the paper's Fig. 3a tenant set (ResNet-18, MobileNet,
    /// camera, Harris); the streaming-pipeline presets use this to put
    /// [`AppId::Pipeline`] chains on the fabric.  TOML:
    /// `workload.tenant_apps`, an array of 4 app names.
    pub tenant_apps: Option<[AppId; 4]>,
}

impl Default for CloudWorkloadConfig {
    fn default() -> Self {
        CloudWorkloadConfig {
            // Tenants: ResNet-18, MobileNet, camera pipeline, Harris.
            // Rates chosen to load the 8-slice array near saturation
            // (EXPERIMENTS.md records the sweep).
            mean_interarrival_ms: [40.0, 25.0, 40.0, 30.0],
            duration_ms: 10_000.0,
            seed: 0xC6_5A_2023,
            tenant_apps: None,
        }
    }
}

/// Autonomous-system scenario workload (paper §3.2, Fig. 3b).
#[derive(Clone, Debug, PartialEq)]
pub struct EdgeWorkloadConfig {
    /// Camera frame rate (paper: 30 fps).
    pub fps: f64,
    /// Number of simulated frames.
    pub frames: u32,
    /// Event period bounds in frames (paper: uniform 3–7).
    pub event_period_frames: (u32, u32),
    /// RNG seed.
    pub seed: u64,
}

impl Default for EdgeWorkloadConfig {
    fn default() -> Self {
        EdgeWorkloadConfig {
            fps: 30.0,
            frames: 600,
            event_period_frames: (3, 7),
            seed: 0xED_6E_2023,
        }
    }
}

/// Which socket-facing front the serving coordinator runs
/// ([`crate::coordinator::Server`]).  Both fronts share the scheduler
/// side (admission queues, workers, shard executors) and the protocol
/// core, so replies are byte-identical across modes.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ServerModeKind {
    /// Thread-per-connection: the accept loop spawns one blocking
    /// reader thread per client.  Simple and debuggable, but ten
    /// thousand idle connections cost ten thousand parked threads each
    /// waking on a 100 ms read-timeout tick.
    Threaded,
    /// Single nonblocking event loop (epoll on Linux, a portable scan
    /// fallback elsewhere) owning every socket: idle connections cost
    /// nothing, and the binary framing's request ids let one connection
    /// multiplex many in-flight requests.
    Reactor,
}

impl ServerModeKind {
    /// All modes, in documentation order.
    pub const ALL: [ServerModeKind; 2] = [ServerModeKind::Threaded, ServerModeKind::Reactor];

    /// Stable config / display name.
    pub fn name(&self) -> &'static str {
        match self {
            ServerModeKind::Threaded => "threaded",
            ServerModeKind::Reactor => "reactor",
        }
    }

    /// Parse a config name.
    pub fn from_name(name: &str) -> Result<Self> {
        match name {
            "threaded" | "thread-per-conn" | "thread_per_conn" => Ok(ServerModeKind::Threaded),
            "reactor" | "event-loop" | "event_loop" => Ok(ServerModeKind::Reactor),
            other => Err(Error::Config(format!("unknown server mode '{other}'"))),
        }
    }
}

/// Which wire encodings the serving front accepts.  The reactor
/// negotiates per connection from the first byte on the wire: `0xC6`
/// (the binary frame magic, [`crate::coordinator::frame`]) selects the
/// binary framing, anything else the line-oriented text protocol.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum WireProtocolKind {
    /// Accept both encodings, negotiated by the first byte (default).
    Auto,
    /// Text protocol only: a connection opening with the frame magic is
    /// refused.
    Text,
    /// Binary framing only: a connection opening with anything else is
    /// refused.  Reactor mode only — the threaded front speaks text.
    Binary,
}

impl WireProtocolKind {
    /// All protocol selections, in documentation order.
    pub const ALL: [WireProtocolKind; 3] =
        [WireProtocolKind::Auto, WireProtocolKind::Text, WireProtocolKind::Binary];

    /// Stable config / display name.
    pub fn name(&self) -> &'static str {
        match self {
            WireProtocolKind::Auto => "auto",
            WireProtocolKind::Text => "text",
            WireProtocolKind::Binary => "binary",
        }
    }

    /// Parse a config name.
    pub fn from_name(name: &str) -> Result<Self> {
        match name {
            "auto" | "both" => Ok(WireProtocolKind::Auto),
            "text" => Ok(WireProtocolKind::Text),
            "binary" | "framed" => Ok(WireProtocolKind::Binary),
            other => Err(Error::Config(format!("unknown wire protocol '{other}'"))),
        }
    }
}

/// TCP serving-front parameters (`[server]` in TOML) — the worker-pool
/// coordinator of [`crate::coordinator::Server`].
#[derive(Clone, Debug, PartialEq)]
pub struct ServerConfig {
    /// Scheduler worker threads draining the per-tenant admission
    /// queues.  Each worker folds the submissions it drains into a
    /// single scheduler invocation, so SUBMITs arriving concurrently on
    /// different connections batch together.  TOML: `server.workers`.
    pub workers: u32,
    /// Bounded per-tenant admission-queue depth.  A SUBMIT that finds
    /// its tenant's queue full is refused immediately with a `BUSY`
    /// reply (explicit backpressure, never unbounded buffering).
    /// TOML: `server.queue_depth`.
    pub queue_depth: u32,
    /// Upper bound on submissions folded into one scheduler invocation
    /// (one `Leader::serve` batch).  Capped at 64 by validation: the
    /// leader's router enforces a per-tenant in-flight window of 64, and
    /// a batch larger than the window could trip it mid-serve.
    /// TOML: `server.batch_max`.
    pub batch_max: u32,
    /// Socket-facing front: thread-per-connection or the nonblocking
    /// reactor.  TOML: `server.mode`.
    pub mode: ServerModeKind,
    /// Wire encodings accepted (reactor negotiates per connection from
    /// the first byte).  TOML: `server.protocol`.
    pub protocol: WireProtocolKind,
    /// Reactor-only idle reaper: a connection that has not *completed a
    /// request* for this long (raw bytes don't count, so slow-loris
    /// dribbling can't hold a socket) is closed.  `0` disables the
    /// reaper.  TOML: `server.idle_timeout_ms`.
    pub idle_timeout_ms: u64,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            workers: 2,
            queue_depth: 32,
            batch_max: 8,
            mode: ServerModeKind::Threaded,
            protocol: WireProtocolKind::Auto,
            idle_timeout_ms: 0,
        }
    }
}

impl ServerConfig {
    /// Validate internal consistency.
    pub fn validate(&self) -> Result<()> {
        if self.workers == 0 || self.queue_depth == 0 || self.batch_max == 0 {
            return Err(Error::Config(
                "server.workers, server.queue_depth and server.batch_max must be positive".into(),
            ));
        }
        if self.workers > 256 {
            return Err(Error::Config(format!(
                "server.workers ({}) is unreasonably large (max 256)",
                self.workers
            )));
        }
        if self.batch_max > 64 {
            return Err(Error::Config(format!(
                "server.batch_max ({}) exceeds the router's per-tenant in-flight window (64)",
                self.batch_max
            )));
        }
        if self.mode == ServerModeKind::Threaded && self.protocol == WireProtocolKind::Binary {
            return Err(Error::Config(
                "server.protocol = \"binary\" requires server.mode = \"reactor\" \
                 (the threaded front speaks the text protocol only)"
                    .into(),
            ));
        }
        Ok(())
    }
}

/// Which workload a run drives.
#[derive(Clone, Debug, PartialEq)]
pub enum WorkloadConfig {
    /// Multi-tenant cloud scenario.
    Cloud(CloudWorkloadConfig),
    /// Autonomous edge scenario.
    Edge(EdgeWorkloadConfig),
}

/// Root configuration.
#[derive(Clone, Debug, PartialEq)]
pub struct Config {
    /// Architecture geometry.
    pub arch: ArchConfig,
    /// DPR engines.
    pub dpr: DprConfig,
    /// Scheduler + region mechanism.
    pub scheduler: SchedulerConfig,
    /// TCP serving front (worker pool + admission queues).
    pub server: ServerConfig,
    /// Fabric pool (sharding) layout + placement.
    pub pool: PoolConfig,
    /// Energy model, power gating, and power-cap governor.
    pub energy: EnergyConfig,
    /// QoS: priority classes, deadlines, preemptive scheduling.
    pub qos: QosConfig,
    /// NoC bandwidth provisioning: corridors, contention, placement.
    pub noc: NocConfig,
    /// Observability: metrics registry, lifecycle journal, exporters.
    pub obs: ObsConfig,
    /// Workload.
    pub workload: WorkloadConfig,
    /// Directory containing AOT artifacts + manifest.json, or the
    /// `"synthetic"` sentinel for the stub backend's built-in manifest.
    pub artifacts_dir: String,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            arch: ArchConfig::default(),
            dpr: DprConfig::default(),
            scheduler: SchedulerConfig::default(),
            server: ServerConfig::default(),
            pool: PoolConfig::default(),
            energy: EnergyConfig::default(),
            qos: QosConfig::default(),
            noc: NocConfig::default(),
            obs: ObsConfig::default(),
            workload: WorkloadConfig::Cloud(CloudWorkloadConfig::default()),
            artifacts_dir: "artifacts".into(),
        }
    }
}

impl Config {
    /// Parse from TOML text; unspecified fields keep paper defaults.
    pub fn from_toml_text(text: &str) -> Result<Config> {
        let root = TomlValue::parse(text)?;
        Config::from_toml(&root)
    }

    /// Load from a file path.
    pub fn from_file(path: impl AsRef<Path>) -> Result<Config> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::io(path.display().to_string(), e))?;
        Config::from_toml_text(&text)
    }

    /// Populate from a parsed TOML table.
    pub fn from_toml(root: &TomlValue) -> Result<Config> {
        let mut cfg = Config::default();

        if let Some(arch) = root.get("arch") {
            let a = &mut cfg.arch;
            read_u32(arch, "cols", &mut a.cols)?;
            read_u32(arch, "rows", &mut a.rows)?;
            read_u32(arch, "mem_col_period", &mut a.mem_col_period)?;
            read_u32(arch, "glb_banks", &mut a.glb_banks)?;
            read_u32(arch, "glb_bank_kib", &mut a.glb_bank_kib)?;
            read_u32(arch, "glb_bank_bytes_per_cycle", &mut a.glb_bank_bytes_per_cycle)?;
            read_u32(arch, "core_clock_mhz", &mut a.core_clock_mhz)?;
            read_u32(arch, "axi_clock_mhz", &mut a.axi_clock_mhz)?;
            read_u32(arch, "tracks_per_dir", &mut a.tracks_per_dir)?;
            read_u32(arch, "slice_cols", &mut a.slice_cols)?;
        }

        if let Some(dpr) = root.get("dpr") {
            let d = &mut cfg.dpr;
            read_u32(dpr, "axi_word_bits", &mut d.axi_word_bits)?;
            read_u32(dpr, "axi_cycles_per_word", &mut d.axi_cycles_per_word)?;
            read_u32(dpr, "fast_word_bits", &mut d.fast_word_bits)?;
            read_u32(dpr, "pe_config_words", &mut d.pe_config_words)?;
            read_u32(dpr, "mem_config_words", &mut d.mem_config_words)?;
            read_u32(dpr, "route_config_words", &mut d.route_config_words)?;
            read_bool(dpr, "relocation", &mut d.relocation)?;
        }

        if let Some(sched) = root.get("scheduler") {
            let s = &mut cfg.scheduler;
            if let Some(v) = sched.get("region_policy") {
                s.region_policy = RegionPolicyKind::from_name(str_of(v, "scheduler.region_policy")?)?;
            }
            if let Some(v) = sched.get("policy") {
                s.policy = SchedulerPolicyKind::from_name(str_of(v, "scheduler.policy")?)?;
            }
            read_u32(sched, "unit_glb_slices", &mut s.unit_glb_slices)?;
            read_u32(sched, "unit_array_slices", &mut s.unit_array_slices)?;
            if let Some(v) = sched.get("defrag_policy") {
                s.defrag_policy =
                    DefragPolicyKind::from_name(str_of(v, "scheduler.defrag_policy")?)?;
            }
            read_f64(sched, "defrag_threshold", &mut s.defrag_threshold)?;
            if let Some(v) = sched.get("migration_cost_model") {
                s.migration_cost_model = MigrationCostModelKind::from_name(str_of(
                    v,
                    "scheduler.migration_cost_model",
                )?)?;
            }
        }

        if let Some(server) = root.get("server") {
            let s = &mut cfg.server;
            read_u32(server, "workers", &mut s.workers)?;
            read_u32(server, "queue_depth", &mut s.queue_depth)?;
            read_u32(server, "batch_max", &mut s.batch_max)?;
            if let Some(v) = server.get("mode") {
                s.mode = ServerModeKind::from_name(str_of(v, "server.mode")?)?;
            }
            if let Some(v) = server.get("protocol") {
                s.protocol = WireProtocolKind::from_name(str_of(v, "server.protocol")?)?;
            }
            read_u64(server, "idle_timeout_ms", &mut s.idle_timeout_ms)?;
        }

        if let Some(pool) = root.get("pool") {
            let p = &mut cfg.pool;
            read_u32(pool, "shards", &mut p.shards)?;
            if let Some(v) = pool.get("placement") {
                p.placement = PlacementPolicyKind::from_name(str_of(v, "pool.placement")?)?;
            }
            read_u32(pool, "admission_window", &mut p.admission_window)?;
        }

        if let Some(energy) = root.get("energy") {
            let e = &mut cfg.energy;
            read_bool(energy, "enabled", &mut e.enabled)?;
            read_bool(energy, "gating", &mut e.gating)?;
            read_u32(energy, "gate_min_run", &mut e.gate_min_run)?;
            read_u64(energy, "wake_cycles", &mut e.wake_cycles)?;
            read_f64(energy, "pe_active_pj", &mut e.pe_active_pj)?;
            read_f64(energy, "pe_idle_pj", &mut e.pe_idle_pj)?;
            read_f64(energy, "mem_active_pj", &mut e.mem_active_pj)?;
            read_f64(energy, "mem_idle_pj", &mut e.mem_idle_pj)?;
            read_f64(energy, "tile_gated_pj", &mut e.tile_gated_pj)?;
            read_f64(energy, "glb_active_pj", &mut e.glb_active_pj)?;
            read_f64(energy, "glb_idle_pj", &mut e.glb_idle_pj)?;
            read_f64(energy, "glb_gated_pj", &mut e.glb_gated_pj)?;
            read_f64(energy, "glb_stream_pj_per_byte", &mut e.glb_stream_pj_per_byte)?;
            read_f64(energy, "stream_duty", &mut e.stream_duty)?;
            read_f64(energy, "dpr_pj_per_bit", &mut e.dpr_pj_per_bit)?;
            read_f64(energy, "fabric_static_pj", &mut e.fabric_static_pj)?;
            read_f64(energy, "fabric_sleep_pj", &mut e.fabric_sleep_pj)?;
            read_f64(energy, "power_cap_watts", &mut e.power_cap_watts)?;
            read_u64(energy, "power_window_cycles", &mut e.power_window_cycles)?;
        }

        if let Some(qos) = root.get("qos") {
            let q = &mut cfg.qos;
            read_bool(qos, "enabled", &mut q.enabled)?;
            if let Some(v) = qos.get("policy") {
                q.policy = QosPolicyKind::from_name(str_of(v, "qos.policy")?)?;
            }
            read_bool(qos, "preemption", &mut q.preemption)?;
            read_u64(qos, "aging_cycles", &mut q.aging_cycles)?;
            read_u32(qos, "max_victims", &mut q.max_victims)?;
            if let Some(v) = qos.get("tenant_classes") {
                let arr = v.as_arr().ok_or_else(|| {
                    Error::Config("qos.tenant_classes must be an array".into())
                })?;
                if arr.len() != 4 {
                    return Err(Error::Config("qos.tenant_classes needs 4 entries".into()));
                }
                for (i, item) in arr.iter().enumerate() {
                    q.tenant_class[i] =
                        QosClass::from_name(str_of(item, "qos.tenant_classes")?)?;
                }
            }
            if let Some(v) = qos.get("deadline_ms") {
                let arr = v
                    .as_arr()
                    .ok_or_else(|| Error::Config("qos.deadline_ms must be an array".into()))?;
                if arr.len() != 4 {
                    return Err(Error::Config("qos.deadline_ms needs 4 entries".into()));
                }
                for (i, item) in arr.iter().enumerate() {
                    q.deadline_ms[i] = item.as_float().ok_or_else(|| {
                        Error::Config("qos.deadline_ms entries must be numbers".into())
                    })?;
                }
            }
        }

        if let Some(noc) = root.get("noc") {
            let n = &mut cfg.noc;
            read_bool(noc, "enabled", &mut n.enabled)?;
            if let Some(v) = noc.get("placement") {
                n.placement = NocPlacementKind::from_name(str_of(v, "noc.placement")?)?;
            }
            read_f64(noc, "comm_fraction", &mut n.comm_fraction)?;
            read_bool(noc, "stream_affinity", &mut n.stream_affinity)?;
            read_bool(noc, "defrag_align", &mut n.defrag_align)?;
        }

        if let Some(obs) = root.get("obs") {
            let o = &mut cfg.obs;
            read_bool(obs, "enabled", &mut o.enabled)?;
            let mut cap = o.journal_cap as u64;
            read_u64(obs, "journal_cap", &mut cap)?;
            o.journal_cap = cap as usize;
            read_bool(obs, "provenance", &mut o.provenance)?;
            let mut pcap = o.provenance_cap as u64;
            read_u64(obs, "provenance_cap", &mut pcap)?;
            o.provenance_cap = pcap as usize;
            read_bool(obs, "watchdog", &mut o.watchdog)?;
            let mut fast = o.slo_fast_window as u64;
            read_u64(obs, "slo_fast_window", &mut fast)?;
            o.slo_fast_window = fast as usize;
            let mut slow = o.slo_slow_window as u64;
            read_u64(obs, "slo_slow_window", &mut slow)?;
            o.slo_slow_window = slow as usize;
            read_f64(obs, "slo_budget", &mut o.slo_budget)?;
            read_f64(obs, "burn_fast", &mut o.burn_fast)?;
            read_f64(obs, "burn_slow", &mut o.burn_slow)?;
            read_f64(obs, "anomaly_sigma", &mut o.anomaly_sigma)?;
            let mut wcap = o.watch_queue_cap as u64;
            read_u64(obs, "watch_queue_cap", &mut wcap)?;
            o.watch_queue_cap = wcap as usize;
        }

        if let Some(wl) = root.get("workload") {
            let kind = wl
                .get("kind")
                .map(|v| str_of(v, "workload.kind"))
                .transpose()?
                .unwrap_or("cloud");
            match kind {
                "cloud" => {
                    let mut c = CloudWorkloadConfig::default();
                    read_f64(wl, "duration_ms", &mut c.duration_ms)?;
                    read_u64(wl, "seed", &mut c.seed)?;
                    if let Some(v) = wl.get("mean_interarrival_ms") {
                        let arr = v.as_arr().ok_or_else(|| {
                            Error::Config("mean_interarrival_ms must be an array".into())
                        })?;
                        if arr.len() != 4 {
                            return Err(Error::Config(
                                "mean_interarrival_ms needs 4 tenant entries".into(),
                            ));
                        }
                        for (i, item) in arr.iter().enumerate() {
                            c.mean_interarrival_ms[i] = item.as_float().ok_or_else(|| {
                                Error::Config("mean_interarrival_ms entries must be numbers".into())
                            })?;
                        }
                    }
                    if let Some(v) = wl.get("tenant_apps") {
                        let arr = v.as_arr().ok_or_else(|| {
                            Error::Config("workload.tenant_apps must be an array".into())
                        })?;
                        if arr.len() != 4 {
                            return Err(Error::Config(
                                "workload.tenant_apps needs 4 tenant entries".into(),
                            ));
                        }
                        let mut apps = [AppId::ResNet18; 4];
                        for (i, item) in arr.iter().enumerate() {
                            apps[i] = AppId::from_name(str_of(item, "workload.tenant_apps")?)?;
                        }
                        c.tenant_apps = Some(apps);
                    }
                    cfg.workload = WorkloadConfig::Cloud(c);
                }
                "edge" => {
                    let mut e = EdgeWorkloadConfig::default();
                    read_f64(wl, "fps", &mut e.fps)?;
                    read_u32(wl, "frames", &mut e.frames)?;
                    read_u64(wl, "seed", &mut e.seed)?;
                    if let Some(v) = wl.get("event_period_frames") {
                        let arr = v.as_arr().ok_or_else(|| {
                            Error::Config("event_period_frames must be an array".into())
                        })?;
                        if arr.len() != 2 {
                            return Err(Error::Config("event_period_frames needs [lo, hi]".into()));
                        }
                        let lo = arr[0].as_int().unwrap_or(-1);
                        let hi = arr[1].as_int().unwrap_or(-1);
                        if lo < 0 || hi < lo {
                            return Err(Error::Config("bad event_period_frames bounds".into()));
                        }
                        e.event_period_frames = (lo as u32, hi as u32);
                    }
                    cfg.workload = WorkloadConfig::Edge(e);
                }
                other => return Err(Error::Config(format!("unknown workload kind '{other}'"))),
            }
        }

        if let Some(v) = root.lookup("runtime.artifacts_dir") {
            cfg.artifacts_dir = str_of(v, "runtime.artifacts_dir")?.to_string();
        }

        cfg.validate()?;
        Ok(cfg)
    }

    /// Validate the whole configuration.
    pub fn validate(&self) -> Result<()> {
        self.arch.validate()?;
        self.dpr.validate()?;
        self.server.validate()?;
        self.pool.validate()?;
        self.energy.validate()?;
        self.qos.validate()?;
        self.noc.validate()?;
        self.obs.validate()?;
        let s = &self.scheduler;
        if s.unit_array_slices == 0 || s.unit_glb_slices == 0 {
            return Err(Error::Config("unit region sizes must be positive".into()));
        }
        if !(0.0..=1.0).contains(&s.defrag_threshold) {
            return Err(Error::Config(format!(
                "scheduler.defrag_threshold ({}) must be within [0, 1]",
                s.defrag_threshold
            )));
        }
        if s.unit_array_slices > self.arch.array_slices() {
            return Err(Error::Config(format!(
                "unit_array_slices ({}) exceeds total array slices ({})",
                s.unit_array_slices,
                self.arch.array_slices()
            )));
        }
        if s.unit_glb_slices > self.arch.glb_slices() {
            return Err(Error::Config(format!(
                "unit_glb_slices ({}) exceeds total GLB slices ({})",
                s.unit_glb_slices,
                self.arch.glb_slices()
            )));
        }
        match &self.workload {
            WorkloadConfig::Cloud(c) => {
                if c.duration_ms <= 0.0 || c.mean_interarrival_ms.iter().any(|&r| r <= 0.0) {
                    return Err(Error::Config("cloud workload rates must be positive".into()));
                }
            }
            WorkloadConfig::Edge(e) => {
                if e.fps <= 0.0 || e.frames == 0 {
                    return Err(Error::Config("edge workload needs fps > 0, frames > 0".into()));
                }
                if e.event_period_frames.0 > e.event_period_frames.1 {
                    return Err(Error::Config("event period lo > hi".into()));
                }
            }
        }
        Ok(())
    }
}

fn str_of<'a>(v: &'a TomlValue, what: &str) -> Result<&'a str> {
    v.as_str()
        .ok_or_else(|| Error::Config(format!("{what} must be a string")))
}

fn read_u32(table: &TomlValue, key: &str, out: &mut u32) -> Result<()> {
    if let Some(v) = table.get(key) {
        let i = v
            .as_int()
            .ok_or_else(|| Error::Config(format!("{key} must be an integer")))?;
        if i < 0 || i > u32::MAX as i64 {
            return Err(Error::Config(format!("{key} out of range: {i}")));
        }
        *out = i as u32;
    }
    Ok(())
}

fn read_u64(table: &TomlValue, key: &str, out: &mut u64) -> Result<()> {
    if let Some(v) = table.get(key) {
        let i = v
            .as_int()
            .ok_or_else(|| Error::Config(format!("{key} must be an integer")))?;
        if i < 0 {
            return Err(Error::Config(format!("{key} must be non-negative")));
        }
        *out = i as u64;
    }
    Ok(())
}

fn read_f64(table: &TomlValue, key: &str, out: &mut f64) -> Result<()> {
    if let Some(v) = table.get(key) {
        *out = v
            .as_float()
            .ok_or_else(|| Error::Config(format!("{key} must be a number")))?;
    }
    Ok(())
}

fn read_bool(table: &TomlValue, key: &str, out: &mut bool) -> Result<()> {
    if let Some(v) = table.get(key) {
        *out = v
            .as_bool()
            .ok_or_else(|| Error::Config(format!("{key} must be a boolean")))?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_matches_paper_geometry() {
        let a = ArchConfig::default();
        a.validate().unwrap();
        assert_eq!(a.pe_tiles(), 384);
        assert_eq!(a.mem_tiles(), 128);
        assert_eq!(a.array_slices(), 8);
        assert_eq!(a.glb_slices(), 32);
        assert_eq!(a.pe_tiles_per_slice(), 48);
        assert_eq!(a.mem_tiles_per_slice(), 16);
        assert_eq!(a.glb_slice_bytes(), 128 * 1024);
    }

    #[test]
    fn default_config_validates() {
        Config::default().validate().unwrap();
    }

    #[test]
    fn toml_overrides_apply() {
        let cfg = Config::from_toml_text(
            "[arch]\ncols = 16\nglb_banks = 16\n[scheduler]\nregion_policy = \"fixed\"\npolicy = \"fcfs\"\n",
        )
        .unwrap();
        assert_eq!(cfg.arch.cols, 16);
        assert_eq!(cfg.arch.array_slices(), 4);
        assert_eq!(cfg.scheduler.region_policy, RegionPolicyKind::FixedSize);
        assert_eq!(cfg.scheduler.policy, SchedulerPolicyKind::FcfsFirstFit);
    }

    #[test]
    fn edge_workload_parse() {
        let cfg = Config::from_toml_text(
            "[workload]\nkind = \"edge\"\nfps = 60.0\nframes = 100\nevent_period_frames = [2, 5]\n",
        )
        .unwrap();
        match cfg.workload {
            WorkloadConfig::Edge(e) => {
                assert_eq!(e.fps, 60.0);
                assert_eq!(e.frames, 100);
                assert_eq!(e.event_period_frames, (2, 5));
            }
            _ => panic!("expected edge workload"),
        }
    }

    #[test]
    fn cloud_workload_rates_parse() {
        let cfg = Config::from_toml_text(
            "[workload]\nkind = \"cloud\"\nmean_interarrival_ms = [10.0, 20.0, 30.0, 40.0]\n",
        )
        .unwrap();
        match cfg.workload {
            WorkloadConfig::Cloud(c) => assert_eq!(c.mean_interarrival_ms, [10.0, 20.0, 30.0, 40.0]),
            _ => panic!("expected cloud workload"),
        }
    }

    #[test]
    fn invalid_geometry_rejected() {
        // cols not a multiple of slice_cols
        assert!(Config::from_toml_text("[arch]\ncols = 30\n").is_err());
        // glb banks don't divide across slices
        assert!(Config::from_toml_text("[arch]\nglb_banks = 30\n").is_err());
        // zero clocks
        assert!(Config::from_toml_text("[arch]\ncore_clock_mhz = 0\n").is_err());
    }

    #[test]
    fn server_knobs_parse_and_validate() {
        let cfg = Config::from_toml_text("[server]\nworkers = 4\nqueue_depth = 128\nbatch_max = 16\n")
            .unwrap();
        assert_eq!(cfg.server.workers, 4);
        assert_eq!(cfg.server.queue_depth, 128);
        assert_eq!(cfg.server.batch_max, 16);
        // defaults when the section is absent
        let d = Config::default().server;
        assert_eq!((d.workers, d.queue_depth, d.batch_max), (2, 32, 8));
        assert_eq!(d.mode, ServerModeKind::Threaded);
        assert_eq!(d.protocol, WireProtocolKind::Auto);
        assert_eq!(d.idle_timeout_ms, 0);
        // zero knobs rejected
        assert!(Config::from_toml_text("[server]\nworkers = 0\n").is_err());
        assert!(Config::from_toml_text("[server]\nqueue_depth = 0\n").is_err());
        assert!(Config::from_toml_text("[server]\nbatch_max = 0\n").is_err());
        assert!(Config::from_toml_text("[server]\nworkers = 1000\n").is_err());
        // batch_max must stay within the router's in-flight window
        assert!(Config::from_toml_text("[server]\nbatch_max = 100\n").is_err());
    }

    #[test]
    fn server_mode_and_protocol_parse_and_validate() {
        let cfg = Config::from_toml_text(
            "[server]\nmode = \"reactor\"\nprotocol = \"binary\"\nidle_timeout_ms = 250\n",
        )
        .unwrap();
        assert_eq!(cfg.server.mode, ServerModeKind::Reactor);
        assert_eq!(cfg.server.protocol, WireProtocolKind::Binary);
        assert_eq!(cfg.server.idle_timeout_ms, 250);
        // name round-trips plus aliases
        for kind in ServerModeKind::ALL {
            assert_eq!(ServerModeKind::from_name(kind.name()).unwrap(), kind);
        }
        assert_eq!(ServerModeKind::from_name("event-loop").unwrap(), ServerModeKind::Reactor);
        for kind in WireProtocolKind::ALL {
            assert_eq!(WireProtocolKind::from_name(kind.name()).unwrap(), kind);
        }
        assert_eq!(WireProtocolKind::from_name("framed").unwrap(), WireProtocolKind::Binary);
        // unknown names rejected
        assert!(Config::from_toml_text("[server]\nmode = \"magic\"\n").is_err());
        assert!(Config::from_toml_text("[server]\nprotocol = \"magic\"\n").is_err());
        // binary-only needs the reactor front (the threaded one is text)
        assert!(Config::from_toml_text("[server]\nprotocol = \"binary\"\n").is_err());
        let ok = Config::from_toml_text("[server]\nmode = \"reactor\"\nprotocol = \"text\"\n");
        assert!(ok.is_ok());
    }

    #[test]
    fn invalid_policy_rejected() {
        assert!(Config::from_toml_text("[scheduler]\nregion_policy = \"magic\"\n").is_err());
        assert!(Config::from_toml_text("[scheduler]\npolicy = \"magic\"\n").is_err());
    }

    #[test]
    fn invalid_workload_rejected() {
        assert!(Config::from_toml_text("[workload]\nkind = \"cloud\"\nduration_ms = -5.0\n").is_err());
        assert!(Config::from_toml_text("[workload]\nkind = \"edge\"\nframes = 0\n").is_err());
        assert!(
            Config::from_toml_text("[workload]\nkind = \"cloud\"\nmean_interarrival_ms = [1.0]\n")
                .is_err()
        );
    }

    #[test]
    fn defrag_knobs_parse_and_validate() {
        let cfg = Config::from_toml_text(
            "[scheduler]\ndefrag_policy = \"cost-aware\"\ndefrag_threshold = 0.4\nmigration_cost_model = \"dpr-only\"\n",
        )
        .unwrap();
        assert_eq!(cfg.scheduler.defrag_policy, DefragPolicyKind::CostAware);
        assert_eq!(cfg.scheduler.defrag_threshold, 0.4);
        assert_eq!(cfg.scheduler.migration_cost_model, MigrationCostModelKind::DprOnly);
        // defaults: migration off, honest cost model
        let d = SchedulerConfig::default();
        assert_eq!(d.defrag_policy, DefragPolicyKind::Off);
        assert_eq!(d.migration_cost_model, MigrationCostModelKind::Full);
        assert!((0.0..=1.0).contains(&d.defrag_threshold));
        // bad values rejected
        assert!(Config::from_toml_text("[scheduler]\ndefrag_policy = \"magic\"\n").is_err());
        assert!(Config::from_toml_text("[scheduler]\nmigration_cost_model = \"magic\"\n").is_err());
        assert!(Config::from_toml_text("[scheduler]\ndefrag_threshold = 1.5\n").is_err());
    }

    #[test]
    fn defrag_names_round_trip() {
        for kind in DefragPolicyKind::ALL {
            assert_eq!(DefragPolicyKind::from_name(kind.name()).unwrap(), kind);
        }
        for kind in MigrationCostModelKind::ALL {
            assert_eq!(MigrationCostModelKind::from_name(kind.name()).unwrap(), kind);
        }
    }

    #[test]
    fn pool_knobs_parse_and_validate() {
        let cfg = Config::from_toml_text(
            "[pool]\nshards = 4\nplacement = \"best-fit\"\nadmission_window = 16\n",
        )
        .unwrap();
        assert_eq!(cfg.pool.shards, 4);
        assert_eq!(cfg.pool.placement, PlacementPolicyKind::BestFit);
        assert_eq!(cfg.pool.admission_window, 16);
        // defaults: single shard, least-loaded, no admission cap —
        // exactly the pre-pool behavior
        let d = PoolConfig::default();
        assert_eq!(d.shards, 1);
        assert_eq!(d.placement, PlacementPolicyKind::LeastLoaded);
        assert_eq!(d.admission_window, 0);
        // bad values rejected
        assert!(Config::from_toml_text("[pool]\nshards = 0\n").is_err());
        assert!(Config::from_toml_text("[pool]\nshards = 100\n").is_err());
        assert!(Config::from_toml_text("[pool]\nplacement = \"magic\"\n").is_err());
    }

    #[test]
    fn placement_policy_names_round_trip() {
        for kind in PlacementPolicyKind::ALL {
            assert_eq!(PlacementPolicyKind::from_name(kind.name()).unwrap(), kind);
        }
        assert_eq!(
            PlacementPolicyKind::from_name("affinity").unwrap(),
            PlacementPolicyKind::Sticky
        );
    }

    #[test]
    fn region_policy_names_round_trip() {
        for kind in RegionPolicyKind::ALL {
            assert_eq!(RegionPolicyKind::from_name(kind.name()).unwrap(), kind);
        }
    }

    #[test]
    fn scheduler_policy_names_round_trip() {
        for kind in [
            SchedulerPolicyKind::GreedyThroughput,
            SchedulerPolicyKind::FcfsFirstFit,
            SchedulerPolicyKind::FairShare,
            SchedulerPolicyKind::ShortestJobFirst,
            SchedulerPolicyKind::EnergyAware,
        ] {
            assert_eq!(SchedulerPolicyKind::from_name(kind.name()).unwrap(), kind);
        }
    }

    #[test]
    fn energy_knobs_parse_and_validate() {
        let cfg = Config::from_toml_text(
            "[energy]\nenabled = true\ngating = false\ngate_min_run = 2\nwake_cycles = 128\n\
             pe_active_pj = 10.0\npower_cap_watts = 2.5\npower_window_cycles = 25000\n",
        )
        .unwrap();
        assert!(cfg.energy.enabled);
        assert!(!cfg.energy.gating);
        assert_eq!(cfg.energy.gate_min_run, 2);
        assert_eq!(cfg.energy.wake_cycles, 128);
        assert_eq!(cfg.energy.pe_active_pj, 10.0);
        assert_eq!(cfg.energy.power_cap_watts, 2.5);
        assert_eq!(cfg.energy.power_window_cycles, 25_000);
        // defaults: accounting off, gating armed, uncapped
        let d = EnergyConfig::default();
        assert!(!d.enabled);
        assert!(d.gating);
        assert_eq!(d.power_cap_watts, 0.0);
        d.validate().unwrap();
        // bad values rejected
        assert!(Config::from_toml_text("[energy]\npe_active_pj = -1.0\n").is_err());
        assert!(Config::from_toml_text("[energy]\nstream_duty = 1.5\n").is_err());
        assert!(Config::from_toml_text("[energy]\ngate_min_run = 0\n").is_err());
        assert!(Config::from_toml_text("[energy]\npower_window_cycles = 0\n").is_err());
    }

    #[test]
    fn qos_knobs_parse_and_validate() {
        let cfg = Config::from_toml_text(
            "[qos]\nenabled = true\npolicy = \"edf\"\npreemption = true\naging_cycles = 1000000\n\
             max_victims = 2\ntenant_classes = [\"best-effort\", \"interactive\", \"critical\", \"critical\"]\n\
             deadline_ms = [0.0, 5.0, 8.0, 6.0]\n",
        )
        .unwrap();
        assert!(cfg.qos.enabled);
        assert_eq!(cfg.qos.policy, QosPolicyKind::Edf);
        assert!(cfg.qos.preemption);
        assert_eq!(cfg.qos.aging_cycles, 1_000_000);
        assert_eq!(cfg.qos.max_victims, 2);
        assert_eq!(
            cfg.qos.tenant_class,
            [QosClass::BestEffort, QosClass::Interactive, QosClass::Critical, QosClass::Critical]
        );
        assert_eq!(cfg.qos.deadline_ms, [0.0, 5.0, 8.0, 6.0]);
        // defaults: subsystem off, everything BestEffort, no deadlines
        let d = QosConfig::default();
        assert!(!d.enabled);
        assert_eq!(d.policy, QosPolicyKind::Edf);
        assert!(d.preemption);
        assert_eq!(d.tenant_class, [QosClass::BestEffort; 4]);
        d.validate().unwrap();
        // bad values rejected
        assert!(Config::from_toml_text("[qos]\npolicy = \"magic\"\n").is_err());
        assert!(Config::from_toml_text("[qos]\ntenant_classes = [\"critical\"]\n").is_err());
        assert!(Config::from_toml_text("[qos]\ntenant_classes = [\"x\",\"x\",\"x\",\"x\"]\n").is_err());
        assert!(Config::from_toml_text("[qos]\ndeadline_ms = [-1.0, 0.0, 0.0, 0.0]\n").is_err());
        assert!(Config::from_toml_text("[qos]\nmax_victims = 0\n").is_err());
    }

    #[test]
    fn obs_knobs_parse_and_validate() {
        let cfg = Config::from_toml_text(
            "[obs]\nenabled = true\njournal_cap = 1024\nprovenance = true\nprovenance_cap = 128\n\
             watchdog = true\nslo_fast_window = 8\nslo_slow_window = 64\nslo_budget = 0.05\n\
             burn_fast = 10.0\nburn_slow = 3.0\nanomaly_sigma = 2.5\nwatch_queue_cap = 16\n",
        )
        .unwrap();
        assert!(cfg.obs.enabled && cfg.obs.provenance && cfg.obs.watchdog);
        assert_eq!(cfg.obs.journal_cap, 1024);
        assert_eq!(cfg.obs.provenance_cap, 128);
        assert_eq!((cfg.obs.slo_fast_window, cfg.obs.slo_slow_window), (8, 64));
        assert_eq!(cfg.obs.slo_budget, 0.05);
        assert_eq!((cfg.obs.burn_fast, cfg.obs.burn_slow), (10.0, 3.0));
        assert_eq!(cfg.obs.anomaly_sigma, 2.5);
        assert_eq!(cfg.obs.watch_queue_cap, 16);
        // defaults: everything off, caps positive
        let d = ObsConfig::default();
        assert!(!d.enabled && !d.provenance && !d.watchdog);
        d.validate().unwrap();
        // bad combinations rejected
        assert!(Config::from_toml_text("[obs]\nprovenance = true\n").is_err());
        assert!(Config::from_toml_text("[obs]\nwatchdog = true\n").is_err());
        assert!(Config::from_toml_text("[obs]\nenabled = true\njournal_cap = 0\n").is_err());
        assert!(Config::from_toml_text(
            "[obs]\nenabled = true\nprovenance = true\nprovenance_cap = 0\n"
        )
        .is_err());
        assert!(Config::from_toml_text(
            "[obs]\nenabled = true\nwatchdog = true\nslo_fast_window = 64\nslo_slow_window = 8\n"
        )
        .is_err());
        assert!(Config::from_toml_text(
            "[obs]\nenabled = true\nwatchdog = true\nslo_budget = 0.0\n"
        )
        .is_err());
        assert!(Config::from_toml_text("[obs]\nenabled = true\nwatch_queue_cap = 0\n").is_err());
    }

    #[test]
    fn qos_class_order_and_names_round_trip() {
        assert!(QosClass::BestEffort < QosClass::Interactive);
        assert!(QosClass::Interactive < QosClass::Critical);
        for class in QosClass::ALL {
            assert_eq!(QosClass::from_name(class.name()).unwrap(), class);
        }
        assert_eq!(QosClass::from_name("besteffort").unwrap(), QosClass::BestEffort);
        for kind in [QosPolicyKind::Fifo, QosPolicyKind::Edf] {
            assert_eq!(QosPolicyKind::from_name(kind.name()).unwrap(), kind);
        }
        // defaults resolve per tenant only when enabled
        let mut q = QosConfig::default();
        q.tenant_class = [QosClass::Critical; 4];
        q.deadline_ms = [2.0; 4];
        assert_eq!(q.class_of_tenant(1), QosClass::BestEffort, "disabled ⇒ BestEffort");
        assert_eq!(q.deadline_of_tenant(1, 100, 500_000), None);
        q.enabled = true;
        assert_eq!(q.class_of_tenant(1), QosClass::Critical);
        assert_eq!(q.deadline_of_tenant(1, 100, 500_000), Some(100 + 1_000_000));
    }

    #[test]
    fn energy_aware_policy_names_round_trip() {
        assert_eq!(
            SchedulerPolicyKind::from_name("energy-aware").unwrap(),
            SchedulerPolicyKind::EnergyAware
        );
        assert_eq!(
            PlacementPolicyKind::from_name("energy_aware").unwrap(),
            PlacementPolicyKind::EnergyAware
        );
        assert_eq!(PlacementPolicyKind::ALL.len(), 4);
    }
}
