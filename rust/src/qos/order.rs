//! Ready-frontier ordering: strict classes, EDF within a class, aging.

use crate::config::QosClass;
use crate::scheduler::ReadyTask;

/// Class a task is *ordered* as: its own class, except that a
/// BestEffort task whose *request* has been in the system at least
/// `aging_cycles` is promoted to Interactive ordering (the starvation
/// guard).  Aging is measured from the request's arrival — not from the
/// instance's last ready transition — so a checkpointed eviction
/// (which re-enters the ready frontier with a fresh `ready_cycle`)
/// can never reset the starvation clock.  Aging affects queue position
/// only — an aged task never gains preemption rights and is still a
/// legal victim.
pub(crate) fn effective_class(rt: &ReadyTask, now: u64, aging_cycles: u64) -> QosClass {
    if rt.class == QosClass::BestEffort
        && aging_cycles > 0
        && now.saturating_sub(rt.arrival_cycle) >= aging_cycles
    {
        QosClass::Interactive
    } else {
        rt.class
    }
}

/// Order the ready frontier under the EDF QoS policy:
///
/// 1. effective class, highest first (strict priority across classes);
/// 2. earliest absolute deadline first within a class (tasks without a
///    deadline sort after every deadlined peer);
/// 3. request arrival, then instance id — the deterministic tie-break
///    that also makes the ordering a stable refinement of FIFO.
pub fn order_ready(mut ready: Vec<ReadyTask>, now: u64, aging_cycles: u64) -> Vec<ReadyTask> {
    ready.sort_by_key(|rt| {
        (
            std::cmp::Reverse(effective_class(rt, now, aging_cycles)),
            rt.deadline.unwrap_or(u64::MAX),
            rt.arrival_cycle,
            rt.instance,
        )
    });
    ready
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tasks::{TaskId, TaskInstanceId};

    fn rt(
        seq: u64,
        class: QosClass,
        deadline: Option<u64>,
        ready: u64,
        arrival: u64,
    ) -> ReadyTask {
        ReadyTask {
            instance: TaskInstanceId { request: seq, node: 0 },
            task: TaskId::new("t"),
            tenant: 0,
            ready_cycle: ready,
            arrival_cycle: arrival,
            class,
            deadline,
            stream_in_bytes: 0,
        }
    }

    #[test]
    fn strict_class_order_then_edf() {
        let ready = vec![
            rt(0, QosClass::BestEffort, None, 0, 0),
            rt(1, QosClass::Critical, Some(900), 0, 5),
            rt(2, QosClass::Critical, Some(100), 0, 9),
            rt(3, QosClass::Interactive, None, 0, 1),
        ];
        let order: Vec<u64> =
            order_ready(ready, 0, 0).iter().map(|r| r.instance.request).collect();
        // critical first (EDF inside), then interactive, then best-effort
        assert_eq!(order, vec![2, 1, 3, 0]);
    }

    #[test]
    fn deadlineless_sorts_after_deadlined_within_class() {
        let ready = vec![
            rt(0, QosClass::Critical, None, 0, 0),
            rt(1, QosClass::Critical, Some(1_000_000), 0, 50),
        ];
        let order: Vec<u64> =
            order_ready(ready, 0, 0).iter().map(|r| r.instance.request).collect();
        assert_eq!(order, vec![1, 0]);
    }

    #[test]
    fn eviction_cannot_reset_the_aging_clock() {
        // a just-preempted instance re-enters the frontier with a fresh
        // ready_cycle; aging still counts from the request's arrival
        let ready = vec![
            rt(0, QosClass::Interactive, None, 999, 999),
            rt(1, QosClass::BestEffort, None, 990, 0), // re-queued at 990, arrived at 0
        ];
        let order: Vec<u64> =
            order_ready(ready, 1_000, 100).iter().map(|r| r.instance.request).collect();
        assert_eq!(order, vec![1, 0], "aged by arrival despite the fresh ready cycle");
    }

    #[test]
    fn aging_promotes_long_waiting_best_effort_over_fresh_interactive() {
        let ready = vec![
            rt(0, QosClass::Interactive, None, 90, 90),
            rt(1, QosClass::BestEffort, None, 0, 0), // waited 100 ≥ 100
        ];
        let aged: Vec<u64> =
            order_ready(ready.clone(), 100, 100).iter().map(|r| r.instance.request).collect();
        // equal effective class: arrival breaks the tie, so the aged
        // task (arrival 0) goes first
        assert_eq!(aged, vec![1, 0]);
        // without aging the interactive task keeps strict priority
        let unaged: Vec<u64> =
            order_ready(ready, 100, 0).iter().map(|r| r.instance.request).collect();
        assert_eq!(unaged, vec![0, 1]);
        // aging never reaches critical ordering
        let vs_critical = vec![
            rt(0, QosClass::Critical, None, 100, 100),
            rt(1, QosClass::BestEffort, None, 0, 0),
        ];
        let order: Vec<u64> = order_ready(vs_critical, 1_000_000, 10)
            .iter()
            .map(|r| r.instance.request)
            .collect();
        assert_eq!(order, vec![0, 1], "aged BestEffort caps at Interactive");
    }
}
