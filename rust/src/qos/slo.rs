//! SLO tracking: per-class deadline-miss rates, slack and latency tails.

use crate::config::QosClass;
use crate::util::stats::{Histogram, Summary};

use super::QosStats;

/// One completed request, as the SLO tracker sees it.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SloRecord {
    /// QoS class of the request.
    pub class: QosClass,
    /// Arrival cycle.
    pub arrival: u64,
    /// Completion cycle.
    pub completion: u64,
    /// Absolute deadline, if the request carried one.
    pub deadline: Option<u64>,
}

impl SloRecord {
    /// Turn-around latency in cycles.
    pub fn latency(&self) -> u64 {
        self.completion.saturating_sub(self.arrival)
    }

    /// Signed slack in cycles (deadline − completion); `None` without a
    /// deadline.  Negative = missed.
    pub fn slack(&self) -> Option<i64> {
        self.deadline.map(|d| d as i64 - self.completion as i64)
    }

    /// Whether the request missed its deadline.
    pub fn missed(&self) -> bool {
        matches!(self.slack(), Some(s) if s < 0)
    }
}

/// Per-class SLO summary (one row of [`QosReport`]).
#[derive(Clone, Debug, PartialEq)]
pub struct ClassSlo {
    /// The class this row summarizes.
    pub class: QosClass,
    /// Requests completed.
    pub completed: u64,
    /// Completed requests that carried a deadline.
    pub deadlined: u64,
    /// Deadlined requests that finished late.
    pub missed: u64,
    /// p50 turn-around latency, cycles.
    pub p50_latency: f64,
    /// p95 turn-around latency, cycles.
    pub p95_latency: f64,
    /// p99 turn-around latency, cycles.
    pub p99_latency: f64,
    /// Mean signed slack over deadlined requests, cycles (negative =
    /// late on average).  0 when nothing carried a deadline.
    pub mean_slack: f64,
    /// Minimum signed slack, cycles (the worst case).  0 when nothing
    /// carried a deadline.
    pub min_slack: f64,
}

impl ClassSlo {
    /// Deadline-miss fraction over deadlined requests (0 when none).
    pub fn miss_rate(&self) -> f64 {
        if self.deadlined == 0 {
            0.0
        } else {
            self.missed as f64 / self.deadlined as f64
        }
    }
}

/// End-of-run QoS report: one [`ClassSlo`] per class plus the
/// preemption counters.
#[derive(Clone, Debug, PartialEq)]
pub struct QosReport {
    /// Per-class rows, lowest class first ([`QosClass::ALL`] order).
    pub per_class: Vec<ClassSlo>,
    /// Preemption passes that evicted at least one victim.
    pub preemptions: u64,
    /// Running tasks checkpointed and evicted.
    pub victims_evicted: u64,
    /// Checkpointed tasks that resumed.
    pub victims_resumed: u64,
    /// Total checkpoint/resume cycles charged.
    pub preempt_cycles: u64,
}

impl QosReport {
    /// The row for `class`.
    pub fn class(&self, class: QosClass) -> &ClassSlo {
        &self.per_class[class.index()]
    }
}

/// Accumulates completed requests and renders [`QosReport`]s.
#[derive(Clone, Debug, Default)]
pub struct SloTracker {
    records: Vec<SloRecord>,
}

impl SloTracker {
    /// Empty tracker.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one completed request.
    pub fn record(&mut self, rec: SloRecord) {
        debug_assert!(rec.completion >= rec.arrival, "completion before arrival");
        self.records.push(rec);
    }

    /// All records.
    pub fn records(&self) -> &[SloRecord] {
        &self.records
    }

    /// Total completed requests.
    pub fn len(&self) -> usize {
        self.records.len()
    }

    /// Whether nothing completed yet.
    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    /// Latency summary for one class (cycles).
    pub fn latency_summary(&self, class: QosClass) -> Summary {
        Summary::from_iter(
            self.records.iter().filter(|r| r.class == class).map(|r| r.latency() as f64),
        )
    }

    /// Slack histogram for one class over `[lo, hi)` cycles with
    /// `buckets` equal-width buckets (negative = missed).
    pub fn slack_histogram(&self, class: QosClass, lo: f64, hi: f64, buckets: usize) -> Histogram {
        let mut h = Histogram::new(lo, hi, buckets);
        for r in self.records.iter().filter(|r| r.class == class) {
            if let Some(s) = r.slack() {
                h.add(s as f64);
            }
        }
        h
    }

    /// Fold into a report, attaching the scheduler's preemption
    /// counters.
    pub fn report(&self, stats: QosStats) -> QosReport {
        let per_class = QosClass::ALL
            .iter()
            .map(|&class| {
                let mut lat = self.latency_summary(class);
                let slacks: Vec<f64> = self
                    .records
                    .iter()
                    .filter(|r| r.class == class)
                    .filter_map(|r| r.slack().map(|s| s as f64))
                    .collect();
                let completed =
                    self.records.iter().filter(|r| r.class == class).count() as u64;
                let missed = self
                    .records
                    .iter()
                    .filter(|r| r.class == class && r.missed())
                    .count() as u64;
                let mut slack = Summary::from_iter(slacks.iter().copied());
                ClassSlo {
                    class,
                    completed,
                    deadlined: slacks.len() as u64,
                    missed,
                    p50_latency: lat.percentile(50.0),
                    p95_latency: lat.percentile(95.0),
                    p99_latency: lat.percentile(99.0),
                    mean_slack: slack.mean(),
                    min_slack: slack.min(),
                }
            })
            .collect();
        QosReport {
            per_class,
            preemptions: stats.preemptions,
            victims_evicted: stats.victims_evicted,
            victims_resumed: stats.victims_resumed,
            preempt_cycles: stats.preempt_cycles,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(class: QosClass, arrival: u64, completion: u64, deadline: Option<u64>) -> SloRecord {
        SloRecord { class, arrival, completion, deadline }
    }

    #[test]
    fn slack_and_miss_math() {
        let on_time = rec(QosClass::Critical, 0, 80, Some(100));
        assert_eq!(on_time.slack(), Some(20));
        assert!(!on_time.missed());
        let late = rec(QosClass::Critical, 0, 130, Some(100));
        assert_eq!(late.slack(), Some(-30));
        assert!(late.missed());
        assert_eq!(rec(QosClass::BestEffort, 0, 50, None).slack(), None);
    }

    #[test]
    fn report_rows_cover_all_classes_in_order() {
        let mut t = SloTracker::new();
        t.record(rec(QosClass::Critical, 0, 80, Some(100)));
        t.record(rec(QosClass::Critical, 0, 130, Some(100)));
        t.record(rec(QosClass::BestEffort, 0, 500, None));
        let r = t.report(QosStats { preemptions: 2, victims_evicted: 3, ..Default::default() });
        assert_eq!(r.per_class.len(), 3);
        assert_eq!(r.per_class[0].class, QosClass::BestEffort);
        assert_eq!(r.per_class[2].class, QosClass::Critical);
        let crit = r.class(QosClass::Critical);
        assert_eq!(crit.completed, 2);
        assert_eq!(crit.deadlined, 2);
        assert_eq!(crit.missed, 1);
        assert!((crit.miss_rate() - 0.5).abs() < 1e-12);
        assert!((crit.mean_slack - (-5.0)).abs() < 1e-12);
        assert_eq!(crit.min_slack, -30.0);
        assert!(crit.p99_latency >= crit.p50_latency);
        let be = r.class(QosClass::BestEffort);
        assert_eq!(be.deadlined, 0);
        assert_eq!(be.miss_rate(), 0.0);
        assert_eq!(r.preemptions, 2);
        assert_eq!(r.victims_evicted, 3);
    }

    #[test]
    fn slack_histogram_counts_only_deadlined_records() {
        let mut t = SloTracker::new();
        t.record(rec(QosClass::Critical, 0, 80, Some(100))); // slack 20
        t.record(rec(QosClass::Critical, 0, 130, Some(100))); // slack -30
        t.record(rec(QosClass::Critical, 0, 10, None));
        let h = t.slack_histogram(QosClass::Critical, -50.0, 50.0, 4);
        assert_eq!(h.count(), 2);
    }
}
