//! Victim selection for checkpointed eviction.
//!
//! Selection is a *dry run*: on a reusable fit-probe scratch borrowed
//! from the region manager ([`crate::regions::RegionManager::fit_probe`]
//! — just the two occupancy maps, never a full manager clone), candidate
//! victims are released one by one in eviction-preference order, and
//! the probe stops at the first prefix whose release makes the blocked
//! demand allocatable ([`crate::regions::RegionManager::can_fit_now`]).
//! Only that prefix is then evicted for real — the engine never evicts
//! a task whose slices would not actually unblock the preemptor.

use crate::abstraction::SliceDemand;
use crate::config::QosClass;
use crate::regions::{FitProbe, RegionId};

/// One running task the preemption engine may evict.
#[derive(Clone, Copy, Debug)]
pub struct VictimCandidate {
    /// The region the task runs on.
    pub region: RegionId,
    /// The task's class (strictly below the preemptor's — the caller
    /// filters).
    pub class: QosClass,
    /// Absolute deadline, if any.
    pub deadline: Option<u64>,
    /// Cycles of execution the task still has ahead of it.
    pub remaining: u64,
}

/// Order candidates by eviction preference: lowest class first, then
/// latest deadline (no deadline counts as latest), then longest
/// remaining runway — evicting the long-runway task frees capacity for
/// the longest time — then region id for determinism.
pub(crate) fn eviction_order(candidates: &mut [VictimCandidate]) {
    candidates.sort_by_key(|c| {
        (
            c.class,
            std::cmp::Reverse(c.deadline.unwrap_or(u64::MAX)),
            std::cmp::Reverse(c.remaining),
            c.region,
        )
    });
}

/// Pick the victim prefix (at most `max_victims`, in
/// [`eviction_order`]) whose eviction makes `demand` allocatable.
/// Returns `None` when no prefix within the cap unblocks the demand —
/// in which case nothing should be evicted at all.
///
/// The dry run happens on `probe`, a reusable scratch the caller builds
/// once per preemption pass ([`crate::regions::RegionManager::fit_probe`])
/// and this function rewinds before each evaluation — repeated
/// what-ifs over several blocked options share one pair of scratch
/// maps instead of cloning the region manager per call.
pub fn select_victims(
    probe: &mut FitProbe<'_>,
    candidates: &[VictimCandidate],
    demand: &SliceDemand,
    max_victims: usize,
) -> Option<Vec<RegionId>> {
    if candidates.is_empty() || max_victims == 0 {
        return None;
    }
    probe.reset();
    let mut chosen = Vec::new();
    for c in candidates.iter().take(max_victims) {
        if probe.release(c.region).is_err() {
            // a candidate that is not actually allocated is a caller bug
            debug_assert!(false, "victim candidate {} not allocated", c.region);
            return None;
        }
        chosen.push(c.region);
        if probe.can_fit_now(demand) {
            return Some(chosen);
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ArchConfig, RegionPolicyKind, SchedulerConfig};

    fn mgr() -> RegionManager {
        let sched = SchedulerConfig {
            region_policy: RegionPolicyKind::FlexibleShape,
            ..SchedulerConfig::default()
        };
        RegionManager::new(&ArchConfig::default(), &sched)
    }

    fn cand(region: RegionId, class: QosClass, deadline: Option<u64>, remaining: u64) -> VictimCandidate {
        VictimCandidate { region, class, deadline, remaining }
    }

    #[test]
    fn eviction_order_prefers_lowest_class_latest_deadline_longest_runway() {
        let mut cands = vec![
            cand(RegionId(0), QosClass::Interactive, None, 10),
            cand(RegionId(1), QosClass::BestEffort, Some(100), 10),
            cand(RegionId(2), QosClass::BestEffort, None, 10),
            cand(RegionId(3), QosClass::BestEffort, None, 99),
        ];
        eviction_order(&mut cands);
        let order: Vec<u64> = cands.iter().map(|c| c.region.0).collect();
        // best-effort before interactive; no-deadline before deadlined;
        // longer runway before shorter
        assert_eq!(order, vec![3, 2, 1, 0]);
    }

    #[test]
    fn selects_minimal_prefix_that_unblocks_the_demand() {
        let mut m = mgr();
        // three 2-array-slice tasks + one 2-slice: array fully busy
        let regions: Vec<RegionId> = (0..4)
            .map(|_| {
                m.try_allocate(&SliceDemand::new(4, 2))
                    .expect_allocated("fill")
                    .id
            })
            .collect();
        let cands: Vec<VictimCandidate> = regions
            .iter()
            .map(|&r| cand(r, QosClass::BestEffort, None, 100))
            .collect();
        // camera-a needs 4 array slices: two adjacent victims suffice
        let mut probe = m.fit_probe();
        let victims = select_victims(&mut probe, &cands, &SliceDemand::new(4, 4), 4)
            .expect("must unblock");
        assert_eq!(victims.len(), 2, "prefix stops as soon as the demand fits");
        // the probe never mutated the real manager
        assert_eq!(m.active_count(), 4);
        // the *same* probe is reusable: it rewinds itself per call
        assert!(select_victims(&mut probe, &cands, &SliceDemand::new(4, 4), 1).is_none());
        // an impossible demand refuses too
        assert!(select_victims(&mut probe, &cands, &SliceDemand::new(40, 9), 4).is_none());
        // and after the refusals the full selection still works
        let again = select_victims(&mut probe, &cands, &SliceDemand::new(4, 4), 4)
            .expect("probe state rewinds");
        assert_eq!(again, victims);
    }

    #[test]
    fn empty_candidates_select_nothing() {
        let m = mgr();
        assert!(select_victims(&mut m.fit_probe(), &[], &SliceDemand::new(1, 1), 4).is_none());
    }
}
