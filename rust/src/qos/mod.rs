//! QoS subsystem: priority classes, deadlines, and preemptive
//! scheduling with checkpointed eviction.
//!
//! The paper's headline autonomous-workload win comes from a scheduler
//! that favors latency-critical tasks and can act on that preference
//! immediately through fast DPR.  This module supplies the policy layer
//! the mechanisms enable (Mestra, arXiv:2604.04694, makes the same
//! argument for virtualized CGRAs — eviction/migration is what turns a
//! run-to-completion fabric into a schedulable one):
//!
//! * **Classes + deadlines** — every [`crate::tasks::AppRequest`]
//!   carries a [`QosClass`] (`Critical | Interactive | BestEffort`) and
//!   an optional absolute deadline.  With `qos.policy = "edf"` the ready
//!   frontier is ordered strictly by class, earliest-deadline-first
//!   within a class, with a starvation-proof aging knob
//!   (`qos.aging_cycles`) that promotes long-waiting BestEffort work to
//!   Interactive *ordering* (it still never preempts anyone) —
//!   [`order_ready`].
//! * **Preemption engine** — when a higher-class task's every variant
//!   returns `NoFit` (and defragmentation could not rescue it), the
//!   scheduler checkpoints and evicts running strictly-lower-class
//!   tasks ([`select_victims`]), priced by the existing
//!   [`crate::migration::MigrationCostModel`] checkpoint path; the
//!   victim later resumes via a fast-DPR relaunch of its checkpointed
//!   variant with its remaining cycles, paying the restream plus the
//!   GLB state copy-in.  Evictions and resumes are energy-accounted
//!   exactly like migrations ([`crate::energy`]).
//! * **SLO tracker** — [`SloTracker`] folds completed requests into
//!   per-class deadline-miss rates, slack statistics and p50/p95/p99
//!   latency ([`QosReport`]), surfaced in the sim reports, the `STATS
//!   QOS` wire reply and [`crate::metrics::export::qos_json`].
//!
//! `[qos].enabled = false` (the default) disables every path above;
//! `tests/determinism.rs` holds existing presets to bit-for-bit
//! unchanged traces and reports.

mod order;
mod preempt;
mod slo;

pub use crate::config::{QosClass, QosConfig, QosPolicyKind};
pub use order::order_ready;
pub(crate) use preempt::eviction_order;
pub use preempt::{select_victims, VictimCandidate};
pub use slo::{ClassSlo, QosReport, SloRecord, SloTracker};

use crate::regions::RegionId;
use crate::tasks::{TaskId, TaskInstanceId};

/// Cumulative preemption counters kept by the scheduler.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct QosStats {
    /// Preemption passes that evicted at least one victim.
    pub preemptions: u64,
    /// Individual running tasks checkpointed and evicted.
    pub victims_evicted: u64,
    /// Checkpointed tasks that resumed (relaunched).
    pub victims_resumed: u64,
    /// Total cycles charged for checkpoints and resume copy-ins.
    pub preempt_cycles: u64,
    /// Launches that succeeded only because a preemption ran first.
    pub rescued_by_preemption: u64,
}

/// One eviction performed by the preemption engine — drained by the
/// simulation drivers ([`crate::scheduler::Scheduler::take_preemptions`])
/// for trace lines and invariant checks.
#[derive(Clone, Debug)]
pub struct PreemptionRecord {
    /// The evicted instance.
    pub victim: TaskInstanceId,
    /// Its task.
    pub victim_task: TaskId,
    /// Its class (always strictly below the preemptor's).
    pub victim_class: QosClass,
    /// The region it was evicted from.
    pub victim_region: RegionId,
    /// The blocked instance the eviction ran for.
    pub preemptor: TaskInstanceId,
    /// The preemptor's class.
    pub preemptor_class: QosClass,
    /// Execution cycles the victim still owes at resume.
    pub remaining_cycles: u64,
    /// Checkpoint cycles charged for this eviction.
    pub checkpoint_cycles: u64,
}
