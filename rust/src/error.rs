//! Crate-wide error type.

use thiserror::Error;

/// Unified error type for every layer of the coordinator.
#[derive(Error, Debug)]
pub enum Error {
    /// Malformed or inconsistent configuration.
    #[error("config error: {0}")]
    Config(String),

    /// Config / manifest parse errors (TOML-subset or JSON).
    #[error("parse error at {location}: {message}")]
    Parse {
        /// `file:line:col` or a JSON pointer-ish path.
        location: String,
        /// Human-readable cause.
        message: String,
    },

    /// Resource allocation failures (no free slices, contiguity violated…).
    #[error("allocation error: {0}")]
    Alloc(String),

    /// Scheduler-level failures (unknown task, dependency cycle…).
    #[error("scheduling error: {0}")]
    Sched(String),

    /// DPR engine failures (bitstream missing, bad destination…).
    #[error("DPR error: {0}")]
    Dpr(String),

    /// PJRT runtime failures, wrapping the `xla` crate's error.
    #[error("runtime error: {0}")]
    Runtime(String),

    /// Artifact registry problems (missing file, manifest mismatch…).
    #[error("artifact error: {0}")]
    Artifact(String),

    /// Simulation invariant violations — always a bug, never user input.
    #[error("simulation invariant violated: {0}")]
    SimInvariant(String),

    /// I/O with context.
    #[error("io error on {path}: {source}")]
    Io {
        /// Offending path.
        path: String,
        /// Underlying error.
        #[source]
        source: std::io::Error,
    },
}

impl Error {
    /// Attach a path to an `io::Error`.
    pub fn io(path: impl Into<String>, source: std::io::Error) -> Self {
        Error::Io { path: path.into(), source }
    }

    /// Parse error helper.
    pub fn parse(location: impl Into<String>, message: impl Into<String>) -> Self {
        Error::Parse { location: location.into(), message: message.into() }
    }
}

#[cfg(feature = "xla")]
impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Runtime(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;
