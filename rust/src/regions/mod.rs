//! Execution regions: the paper's first hardware mechanism (§2.3, Fig. 2).
//!
//! An *execution region* is the set of GLB-slices and array-slices a
//! single task runs on.  Four formation mechanisms are modeled, matching
//! Fig. 2 exactly:
//!
//! * [`crate::config::RegionPolicyKind::Baseline`] — the whole CGRA is one
//!   region; subsequent tasks wait (Fig. 2a).
//! * [`crate::config::RegionPolicyKind::FixedSize`] — pre-carved unit
//!   regions; a task takes the best variant that fits one unit and may be
//!   *replicated* into several free units for linear throughput
//!   (Fig. 2b's "unrolled by three").  Tasks that fit no unit fall back
//!   to exclusive whole-machine execution (see DESIGN.md §regions).
//! * [`crate::config::RegionPolicyKind::VariableSize`] — adjacent units
//!   merge into a larger region whose GLB:array ratio stays fixed
//!   (Fig. 2c); any variant fitting the merged budget can be chosen.
//! * [`crate::config::RegionPolicyKind::FlexibleShape`] — GLB-slices and
//!   array-slices are allocated independently and exactly (Fig. 2d, the
//!   paper's contribution).
//!
//! The manager enforces the paper's contiguity restriction ("we limit
//! the placement of GLB-slices and array-slices within an execution
//! region to be contiguous").

mod allocator;
mod region;

pub use allocator::{AllocOutcome, FitProbe, RegionManager};
pub use region::{ExecutionRegion, RegionId};
