//! The region manager: allocation under the four mechanisms of Fig. 2.

use std::collections::BTreeMap;

use crate::abstraction::{CorridorMap, CorridorSpan, SliceDemand, SliceMap, SliceRange};
use crate::config::{ArchConfig, RegionPolicyKind, SchedulerConfig};
use crate::error::{Error, Result};
use crate::noc::span_for;

use super::region::{ExecutionRegion, RegionId};

/// Corridor-bandwidth bookkeeping armed by [`RegionManager::set_noc`].
///
/// Spans are occupied in [`RegionManager`]'s `commit`, released in
/// `release` and moved in `relocate` — the exact lockstep discipline the
/// slice maps follow, so corridor demand can never leak past a region's
/// lifetime (`tests/prop_noc.rs` round-trips it).
#[derive(Clone, Debug)]
struct NocState {
    map: CorridorMap,
    /// GLB banks per corridor (`glb_slices / array_slices`).
    banks_per_corridor: u32,
    /// Live region → the span it occupies.
    spans: BTreeMap<RegionId, CorridorSpan>,
    /// Score flexible placements by projected corridor oversubscription.
    comm_aware: bool,
    /// Worst oversubscription observed at any commit.
    peak_oversub: f64,
}

/// Result of an allocation attempt.
#[derive(Clone, Debug, PartialEq)]
pub enum AllocOutcome {
    /// Region allocated; slices are now busy.
    Allocated(ExecutionRegion),
    /// Cannot fit *right now* — retry when a region is released.
    NoFit,
    /// Can never fit under this mechanism/geometry (the scheduler must
    /// pick another variant or fall back to exclusive execution).
    NeverFits,
}

impl AllocOutcome {
    /// Unwrap an allocation, panicking otherwise.  A test helper only —
    /// production paths must handle `NoFit`/`NeverFits` — so it is
    /// compiled solely for this crate's tests, or for downstream test
    /// suites via the `testutil` feature.
    #[cfg(any(test, feature = "testutil"))]
    pub fn expect_allocated(self, msg: &str) -> ExecutionRegion {
        match self {
            AllocOutcome::Allocated(r) => r,
            other => panic!("{msg}: got {other:?}"),
        }
    }
}

/// Slice-granular allocator implementing the four region mechanisms.
#[derive(Clone, Debug)]
pub struct RegionManager {
    policy: RegionPolicyKind,
    glb: SliceMap,
    array: SliceMap,
    /// Unit region size (fixed / variable mechanisms).
    unit: SliceDemand,
    regions: BTreeMap<RegionId, ExecutionRegion>,
    next_id: u64,
    /// Power-gate free slices ([`crate::energy`]); off by default so the
    /// pre-energy behavior is untouched.
    gating: bool,
    /// Minimum contiguous free run a domain needs before it gates.
    gate_min_run: u32,
    /// Corridor-bandwidth tracking ([`crate::noc`]); `None` (the
    /// default) keeps the pre-NoC behavior bit-for-bit.
    noc: Option<NocState>,
}

impl RegionManager {
    /// Build from architecture + scheduler configuration.
    pub fn new(arch: &ArchConfig, sched: &SchedulerConfig) -> RegionManager {
        RegionManager {
            policy: sched.region_policy,
            glb: SliceMap::new(arch.glb_slices()),
            array: SliceMap::new(arch.array_slices()),
            unit: SliceDemand::new(sched.unit_glb_slices, sched.unit_array_slices),
            regions: BTreeMap::new(),
            next_id: 0,
            gating: false,
            gate_min_run: 1,
            noc: None,
        }
    }

    /// Arm corridor-bandwidth tracking ([`crate::noc`]): one corridor
    /// per array-slice, `tracks_per_dir × slice_cols` tracks each.
    /// Every subsequent commit/release/relocate keeps the corridor map
    /// in lockstep with the slice maps.  With `comm_aware`, flexible
    /// placement additionally scores candidate runs by projected
    /// corridor oversubscription (and honors allocation hints).
    pub fn set_noc(&mut self, arch: &ArchConfig, comm_aware: bool) {
        let corridors = arch.array_slices().max(1);
        let capacity = (arch.tracks_per_dir * arch.slice_cols).max(1);
        let banks_per_corridor = (arch.glb_slices() / corridors).max(1);
        self.noc = Some(NocState {
            map: CorridorMap::new(corridors, capacity),
            banks_per_corridor,
            spans: BTreeMap::new(),
            comm_aware,
            peak_oversub: 1.0,
        });
    }

    /// Whether corridor tracking is armed.
    pub fn noc_enabled(&self) -> bool {
        self.noc.is_some()
    }

    /// The corridor map, when tracking is armed.
    pub fn corridor_map(&self) -> Option<&CorridorMap> {
        self.noc.as_ref().map(|n| &n.map)
    }

    /// The corridor span region `id` occupies (empty when tracking is
    /// off or the region holds no streams).
    pub fn corridor_span(&self, id: RegionId) -> CorridorSpan {
        self.noc
            .as_ref()
            .and_then(|n| n.spans.get(&id).copied())
            .unwrap_or_else(CorridorSpan::empty)
    }

    /// Worst oversubscription along region `id`'s corridor span, the
    /// region's own demand included (1.0 when tracking is off).
    pub fn corridor_slowdown(&self, id: RegionId) -> f64 {
        match &self.noc {
            Some(n) => match n.spans.get(&id) {
                Some(span) => n.map.max_oversub_in(&span.range),
                None => 1.0,
            },
            None => 1.0,
        }
    }

    /// Current worst corridor oversubscription across the fabric — the
    /// pool router's communication-pressure signal.  0.0 when tracking
    /// is off (mirrors the other policy-specific shard gauges).
    pub fn corridor_pressure(&self) -> f64 {
        match &self.noc {
            Some(n) => (0..n.map.corridors()).map(|c| n.map.oversub(c)).fold(1.0, f64::max),
            None => 0.0,
        }
    }

    /// Worst oversubscription observed at any commit since tracking was
    /// armed (1.0 = never contended; 0.0 when tracking is off).
    pub fn corridor_peak_oversub(&self) -> f64 {
        self.noc.as_ref().map(|n| n.peak_oversub).unwrap_or(0.0)
    }

    /// Arm power gating: a free slice is gated exactly when its maximal
    /// free run spans at least `min_run` slices (scattered fragmentation
    /// holes stay awake — they cost idle watts until a defragmentation
    /// pass merges them).  Gating state is *derived* from the occupancy
    /// maps, so release and relocation re-gate vacated slices with no
    /// extra bookkeeping; [`RegionManager::gated_counts`] reads it and
    /// committed allocations report the domains they woke via
    /// [`ExecutionRegion::woken`].
    pub fn set_gating(&mut self, enabled: bool, min_run: u32) {
        self.gating = enabled;
        self.gate_min_run = min_run.max(1);
    }

    /// Whether power gating is armed.
    pub fn gating_enabled(&self) -> bool {
        self.gating
    }

    /// Currently gated free slices, `(glb, array)`.
    pub fn gated_counts(&self) -> (u32, u32) {
        if !self.gating {
            return (0, 0);
        }
        (
            gated_count(&self.glb, self.gate_min_run),
            gated_count(&self.array, self.gate_min_run),
        )
    }

    /// Awake-but-unallocated free slices, `(glb, array)` — the idle
    /// complement of [`RegionManager::gated_counts`].
    pub fn idle_free_counts(&self) -> (u32, u32) {
        let (gg, ga) = self.gated_counts();
        (
            self.glb.free_count() - gg,
            self.array.free_count() - ga,
        )
    }

    /// Active mechanism.
    pub fn policy(&self) -> RegionPolicyKind {
        self.policy
    }

    /// Unit region size (meaningful for fixed/variable).
    pub fn unit(&self) -> SliceDemand {
        self.unit
    }

    /// Number of pre-carved unit regions under fixed/variable.
    pub fn unit_count(&self) -> u32 {
        (self.glb.len() / self.unit.glb_slices).min(self.array.len() / self.unit.array_slices)
    }

    /// Currently allocated regions.
    pub fn active(&self) -> impl Iterator<Item = &ExecutionRegion> {
        self.regions.values()
    }

    /// Number of active regions.
    pub fn active_count(&self) -> usize {
        self.regions.len()
    }

    /// Whether the machine is completely idle.
    pub fn idle(&self) -> bool {
        self.regions.is_empty()
    }

    /// (glb, array) busy fractions.
    pub fn utilization(&self) -> (f64, f64) {
        (
            self.glb.busy_count() as f64 / self.glb.len().max(1) as f64,
            self.array.busy_count() as f64 / self.array.len().max(1) as f64,
        )
    }

    /// (glb, array) external fragmentation.
    pub fn fragmentation(&self) -> (f64, f64) {
        (self.glb.fragmentation(), self.array.fragmentation())
    }

    /// GLB-slice occupancy map (read-only; planner/metrics input).
    pub fn glb_map(&self) -> &SliceMap {
        &self.glb
    }

    /// Array-slice occupancy map (read-only; planner/metrics input).
    pub fn array_map(&self) -> &SliceMap {
        &self.array
    }

    /// Region lookup.
    pub fn region(&self, id: RegionId) -> Option<&ExecutionRegion> {
        self.regions.get(&id)
    }

    /// Whether `demand` could ever be satisfied by this mechanism on an
    /// idle machine (feasibility, not current availability).
    pub fn can_ever_fit(&self, demand: &SliceDemand) -> bool {
        match self.policy {
            RegionPolicyKind::Baseline | RegionPolicyKind::FlexibleShape => {
                demand.glb_slices <= self.glb.len() && demand.array_slices <= self.array.len()
            }
            RegionPolicyKind::FixedSize => demand.fits_within(&self.unit),
            RegionPolicyKind::VariableSize => {
                let k = self.units_needed(demand);
                k > 0 && k <= self.unit_count()
            }
        }
    }

    /// Whether `demand` could be allocated *right now*, without mutating
    /// the maps — the read-only twin of [`RegionManager::try_allocate`].
    /// The fabric-pool router ([`crate::fabric`]) probes every shard with
    /// this before falling back to a cross-shard defragmentation pass.
    pub fn can_fit_now(&self, demand: &SliceDemand) -> bool {
        self.fits_on(&self.glb, &self.array, self.idle(), demand)
    }

    /// The fit predicate behind [`RegionManager::can_fit_now`],
    /// parameterized over the occupancy state so [`FitProbe`] what-ifs
    /// evaluate it against scratch maps without cloning the manager.
    fn fits_on(&self, glb: &SliceMap, array: &SliceMap, idle: bool, demand: &SliceDemand) -> bool {
        if !self.can_ever_fit(demand) {
            return false;
        }
        match self.policy {
            RegionPolicyKind::Baseline => idle,
            RegionPolicyKind::FixedSize => (0..self.unit_count()).any(|i| {
                let g = SliceRange::new(i * self.unit.glb_slices, self.unit.glb_slices);
                let a = SliceRange::new(i * self.unit.array_slices, self.unit.array_slices);
                glb.range_free(&g) && array.range_free(&a)
            }),
            RegionPolicyKind::VariableSize => {
                let k = self.units_needed(demand);
                let total = self.unit_count();
                k <= total
                    && (0..=(total - k)).any(|start| {
                        let g = SliceRange::new(
                            start * self.unit.glb_slices,
                            k * self.unit.glb_slices,
                        );
                        let a = SliceRange::new(
                            start * self.unit.array_slices,
                            k * self.unit.array_slices,
                        );
                        glb.range_free(&g) && array.range_free(&a)
                    })
            }
            RegionPolicyKind::FlexibleShape => {
                array.find_free_run(demand.array_slices).is_some()
                    && glb.find_free_run(demand.glb_slices).is_some()
            }
        }
    }

    /// Borrow a reusable what-if scratch over this manager's occupancy
    /// state.  Dry runs (preemption victim selection, defrag probes)
    /// release regions on the probe and re-evaluate the fit predicate
    /// without ever cloning the manager's region table; [`FitProbe::reset`]
    /// rewinds the scratch to the live state in place, reusing its
    /// allocations across successive what-ifs.
    pub fn fit_probe(&self) -> FitProbe<'_> {
        FitProbe {
            mgr: self,
            glb: self.glb.clone(),
            array: self.array.clone(),
            active: self.regions.len(),
        }
    }

    /// Units needed to cover `demand` when merging (variable mechanism):
    /// both slice classes must be covered by the *same* k (the merged
    /// region keeps the unit's GLB:array ratio, §2.3).
    pub fn units_needed(&self, demand: &SliceDemand) -> u32 {
        let kg = demand.glb_slices.div_ceil(self.unit.glb_slices);
        let ka = demand.array_slices.div_ceil(self.unit.array_slices);
        kg.max(ka).max(1)
    }

    /// Attempt to allocate a region for `demand` under the mechanism.
    pub fn try_allocate(&mut self, demand: &SliceDemand) -> AllocOutcome {
        self.try_allocate_hinted(demand, None)
    }

    /// [`RegionManager::try_allocate`] with an optional array-slice
    /// placement hint (a producer region's position, from the app DAG).
    /// The hint only steers the flexible mechanism under comm-aware NoC
    /// placement — every other configuration ignores it, keeping the
    /// pre-NoC allocation order bit-for-bit.
    pub fn try_allocate_hinted(&mut self, demand: &SliceDemand, hint: Option<u32>) -> AllocOutcome {
        match self.policy {
            RegionPolicyKind::Baseline => self.alloc_baseline(demand),
            RegionPolicyKind::FixedSize => self.alloc_fixed(demand, 1),
            RegionPolicyKind::VariableSize => self.alloc_variable(demand),
            RegionPolicyKind::FlexibleShape => self.alloc_flexible(demand, hint),
        }
    }

    /// Fixed-size only: allocate up to `max_replicas` unit copies
    /// (Fig. 2b's parallel unroll).  Returns as many units as are free,
    /// capped at `max_replicas`; at least one unit must be free.
    pub fn try_allocate_replicated(
        &mut self,
        demand: &SliceDemand,
        max_replicas: u32,
    ) -> AllocOutcome {
        debug_assert_eq!(self.policy, RegionPolicyKind::FixedSize);
        self.alloc_fixed(demand, max_replicas.max(1))
    }

    /// Exclusive whole-machine allocation — the baseline path, also the
    /// fixed-size fallback for tasks that fit no unit.  Requires idle.
    pub fn try_allocate_exclusive(&mut self, demand: &SliceDemand) -> AllocOutcome {
        if demand.glb_slices > self.glb.len() || demand.array_slices > self.array.len() {
            return AllocOutcome::NeverFits;
        }
        if !self.idle() {
            return AllocOutcome::NoFit;
        }
        let glb = SliceRange::new(0, self.glb.len());
        let array = SliceRange::new(0, self.array.len());
        AllocOutcome::Allocated(self.commit(vec![glb], vec![array], 1))
    }

    /// Release a region's slices.
    ///
    /// A region's owned ranges are coalesced *before* release (a
    /// fixed-size task replicated into adjacent units owns several
    /// ranges that form one physical run), so the free list the
    /// defragmentation planner reads is canonical immediately — no lazy
    /// merge pass between a release and the next planning decision.
    pub fn release(&mut self, id: RegionId) -> Result<()> {
        let region = self
            .regions
            .remove(&id)
            .ok_or_else(|| Error::Alloc(format!("release of unknown region {id}")))?;
        for r in coalesce(&region.glb) {
            self.glb.release(&r);
        }
        for r in coalesce(&region.array) {
            self.array.release(&r);
        }
        if let Some(noc) = &mut self.noc {
            if let Some(span) = noc.spans.remove(&id) {
                noc.map.release(&span);
            }
        }
        Ok(())
    }

    /// Move a (contiguous) region's slices to new ranges — the
    /// relocation primitive behind live migration ([`crate::migration`]).
    ///
    /// `None` keeps a slice class in place.  Each new range must have the
    /// same length as the current one and must be free (the region's own
    /// current slices count as free, so overlapping shifts are fine).
    /// On any validation failure the occupancy maps are left exactly as
    /// they were.
    ///
    /// Returns the `(glb, array)` slices the move woke from power
    /// gating — a relocation target inside a gated free run transitions
    /// those domains to active just like an allocation would, and the
    /// migration energy accounting charges the wake ([`crate::energy`]).
    /// Always `(0, 0)` with gating off; the vacated slices re-gate
    /// automatically (gating is derived from the free runs).
    pub fn relocate(
        &mut self,
        id: RegionId,
        new_glb: Option<SliceRange>,
        new_array: Option<SliceRange>,
    ) -> Result<(u32, u32)> {
        let region = self
            .regions
            .get(&id)
            .cloned()
            .ok_or_else(|| Error::Alloc(format!("relocate of unknown region {id}")))?;
        if !region.is_contiguous() {
            return Err(Error::Alloc(format!(
                "cannot relocate non-contiguous region {id} (replicated fixed-size regions are pinned)"
            )));
        }
        let cur_glb = region.glb.first().copied().unwrap_or(SliceRange::empty());
        let cur_arr = region.array.first().copied().unwrap_or(SliceRange::empty());
        let tgt_glb = new_glb.unwrap_or(cur_glb);
        let tgt_arr = new_array.unwrap_or(cur_arr);
        if tgt_glb.len != cur_glb.len || tgt_arr.len != cur_arr.len {
            return Err(Error::Alloc(format!(
                "relocation of {id} must preserve range lengths ({cur_glb}→{tgt_glb}, {cur_arr}→{tgt_arr})"
            )));
        }
        if tgt_glb.end() > self.glb.len() || tgt_arr.end() > self.array.len() {
            return Err(Error::Alloc(format!("relocation target out of bounds for {id}")));
        }
        // Gated domains the targets overlap, measured *before* the
        // region's own (awake) slices are temporarily freed below, so a
        // self-overlapping shift never counts its own slices as woken.
        let woken = if self.gating {
            (
                gated_overlap(&self.glb, &[tgt_glb], self.gate_min_run),
                gated_overlap(&self.array, &[tgt_arr], self.gate_min_run),
            )
        } else {
            (0, 0)
        };
        // Free the region's own slices so self-overlapping shifts pass
        // the target check; restore them if the target is busy.
        self.glb.release(&cur_glb);
        self.array.release(&cur_arr);
        if self.glb.range_free(&tgt_glb) && self.array.range_free(&tgt_arr) {
            self.glb.occupy(&tgt_glb);
            self.array.occupy(&tgt_arr);
            let r = self.regions.get_mut(&id).expect("looked up above");
            r.glb = vec![tgt_glb];
            r.array = vec![tgt_arr];
            if let Some(noc) = &mut self.noc {
                if let Some(old) = noc.spans.remove(&id) {
                    noc.map.release(&old);
                }
                let span = span_for(
                    &[tgt_glb],
                    &[tgt_arr],
                    noc.banks_per_corridor,
                    noc.map.corridors(),
                );
                noc.map.occupy(&span);
                noc.spans.insert(id, span);
            }
            Ok(woken)
        } else {
            self.glb.occupy(&cur_glb);
            self.array.occupy(&cur_arr);
            Err(Error::Alloc(format!("relocation target busy for {id}")))
        }
    }

    /// Render occupancy maps (Fig. 2-style dump).
    pub fn render(&self) -> String {
        format!("GLB   {}\nARRAY {}", self.glb.render(), self.array.render())
    }

    // ---------------------------------------------------------------- impl

    fn commit(
        &mut self,
        glb: Vec<SliceRange>,
        array: Vec<SliceRange>,
        replicas: u32,
    ) -> ExecutionRegion {
        // how many gated domains this allocation wakes (before occupying)
        let (woken_glb, woken_array) = if self.gating {
            (
                gated_overlap(&self.glb, &glb, self.gate_min_run),
                gated_overlap(&self.array, &array, self.gate_min_run),
            )
        } else {
            (0, 0)
        };
        for r in &glb {
            self.glb.occupy(r);
        }
        for r in &array {
            self.array.occupy(r);
        }
        let id = RegionId(self.next_id);
        self.next_id += 1;
        let region = ExecutionRegion { id, glb, array, replicas, woken_glb, woken_array };
        self.regions.insert(id, region.clone());
        if let Some(noc) = &mut self.noc {
            let span =
                span_for(&region.glb, &region.array, noc.banks_per_corridor, noc.map.corridors());
            noc.map.occupy(&span);
            let oversub = noc.map.max_oversub_in(&span.range);
            if oversub > noc.peak_oversub {
                noc.peak_oversub = oversub;
            }
            noc.spans.insert(id, span);
        }
        region
    }

    fn alloc_baseline(&mut self, demand: &SliceDemand) -> AllocOutcome {
        // Fig. 2a: the whole CGRA is one region; a task takes everything.
        self.try_allocate_exclusive(demand)
    }

    fn alloc_fixed(&mut self, demand: &SliceDemand, max_replicas: u32) -> AllocOutcome {
        if !demand.fits_within(&self.unit) {
            return AllocOutcome::NeverFits;
        }
        // Pre-carved unit positions: unit i owns glb [i·ug, ug) and
        // array [i·ua, ua).
        let mut free_units = Vec::new();
        for i in 0..self.unit_count() {
            let g = SliceRange::new(i * self.unit.glb_slices, self.unit.glb_slices);
            let a = SliceRange::new(i * self.unit.array_slices, self.unit.array_slices);
            if self.glb.range_free(&g) && self.array.range_free(&a) {
                free_units.push((g, a));
                if free_units.len() as u32 == max_replicas {
                    break;
                }
            }
        }
        if free_units.is_empty() {
            return AllocOutcome::NoFit;
        }
        let replicas = free_units.len() as u32;
        let (glb, array): (Vec<_>, Vec<_>) = free_units.into_iter().unzip();
        AllocOutcome::Allocated(self.commit(glb, array, replicas))
    }

    fn alloc_variable(&mut self, demand: &SliceDemand) -> AllocOutcome {
        let k = self.units_needed(demand);
        if k > self.unit_count() {
            return AllocOutcome::NeverFits;
        }
        // k *adjacent* units merge into one region (Fig. 2c).
        let total = self.unit_count();
        for start in 0..=(total - k) {
            let g = SliceRange::new(start * self.unit.glb_slices, k * self.unit.glb_slices);
            let a = SliceRange::new(start * self.unit.array_slices, k * self.unit.array_slices);
            if self.glb.range_free(&g) && self.array.range_free(&a) {
                return AllocOutcome::Allocated(self.commit(vec![g], vec![a], 1));
            }
        }
        AllocOutcome::NoFit
    }

    fn alloc_flexible(&mut self, demand: &SliceDemand, hint: Option<u32>) -> AllocOutcome {
        if demand.glb_slices > self.glb.len() || demand.array_slices > self.array.len() {
            return AllocOutcome::NeverFits;
        }
        if let Some((glb, array)) = self.comm_aware_flexible_choice(demand, hint) {
            return AllocOutcome::Allocated(self.commit(vec![glb], vec![array], 1));
        }
        // Decoupled, exact, contiguous allocation (Fig. 2d).  Prefer to
        // anchor the GLB range near the array range's IO columns: first
        // place the array run, then look for a GLB run starting at the
        // proportional bank index, falling back to anywhere.
        let array = match self.array.find_free_run(demand.array_slices) {
            Some(r) => r,
            None => return AllocOutcome::NoFit,
        };
        let banks_per_slice = (self.glb.len() / self.array.len().max(1)).max(1);
        let preferred = array.start * banks_per_slice;
        let glb = self
            .glb
            .find_free_run_from(preferred, demand.glb_slices)
            .or_else(|| self.glb.find_free_run(demand.glb_slices));
        let glb = match glb {
            Some(r) => r,
            None => return AllocOutcome::NoFit,
        };
        AllocOutcome::Allocated(self.commit(vec![glb], vec![array], 1))
    }

    /// Communication-aware flexible placement: enumerate candidate
    /// (array run, GLB run) pairs and pick the one whose corridor span
    /// projects the least oversubscription, breaking ties toward the
    /// producer hint, then the narrowest span, then the leftmost run.
    /// `None` when comm-aware placement is off *or* nothing fits — the
    /// caller then takes the first-fit path (which agrees on fit).
    fn comm_aware_flexible_choice(
        &self,
        demand: &SliceDemand,
        hint: Option<u32>,
    ) -> Option<(SliceRange, SliceRange)> {
        let noc = self.noc.as_ref().filter(|n| n.comm_aware)?;
        let need_a = demand.array_slices;
        let need_g = demand.glb_slices;
        if need_a == 0 || need_g == 0 {
            return None;
        }
        let banks_per_slice = (self.glb.len() / self.array.len().max(1)).max(1);
        // Exhaustive over array anchor positions (the array map is a
        // handful of slices) × per-GLB-run {aligned, leftmost} anchors:
        // deterministic and cheap, with enough freedom to dodge a hot
        // corridor that first-fit would pile onto.
        let mut best: Option<((f64, u32, u32, u32), (SliceRange, SliceRange))> = None;
        for run in self.array.free_runs_ref() {
            if run.len < need_a {
                continue;
            }
            for astart in run.start..=(run.end() - need_a) {
                let array = SliceRange::new(astart, need_a);
                let preferred = astart * banks_per_slice;
                for grun in self.glb.free_runs_ref() {
                    if grun.len < need_g {
                        continue;
                    }
                    let glast = grun.end() - need_g;
                    let aligned = preferred.clamp(grun.start, glast);
                    for (gi, gstart) in [aligned, grun.start].into_iter().enumerate() {
                        if gi == 1 && gstart == aligned {
                            continue;
                        }
                        let glb = SliceRange::new(gstart, need_g);
                        let span = span_for(
                            &[glb],
                            &[array],
                            noc.banks_per_corridor,
                            noc.map.corridors(),
                        );
                        let oversub = noc.map.projected_oversub(&span);
                        let hint_dist = hint.map(|h| h.abs_diff(astart)).unwrap_or(0);
                        let key = (oversub, hint_dist, span.range.len, astart);
                        let better = match &best {
                            None => true,
                            Some((k, _)) => match key.0.total_cmp(&k.0) {
                                std::cmp::Ordering::Less => true,
                                std::cmp::Ordering::Greater => false,
                                std::cmp::Ordering::Equal => {
                                    (key.1, key.2, key.3) < (k.1, k.2, k.3)
                                }
                            },
                        };
                        if better {
                            best = Some((key, (glb, array)));
                        }
                    }
                }
            }
        }
        best.map(|(_, choice)| choice)
    }
}

/// Reusable what-if scratch for fit dry-runs ([`RegionManager::fit_probe`]).
///
/// Holds only the two occupancy maps (a few dozen slices each) — the
/// manager's region table, policy and unit geometry are consulted
/// through the borrow, so building or resetting a probe never touches
/// the heap beyond the slice bitmaps and their run indexes.
#[derive(Debug)]
pub struct FitProbe<'a> {
    mgr: &'a RegionManager,
    glb: SliceMap,
    array: SliceMap,
    active: usize,
}

impl FitProbe<'_> {
    /// Rewind the scratch to the manager's live occupancy state,
    /// reusing the existing map allocations.
    pub fn reset(&mut self) {
        self.glb.clone_from(&self.mgr.glb);
        self.array.clone_from(&self.mgr.array);
        self.active = self.mgr.regions.len();
    }

    /// What-if release of `id`'s slices on the scratch maps.  The
    /// region table itself is untouched; releasing the same region
    /// twice between resets is a caller bug (double-release asserts in
    /// debug builds, like the underlying maps).
    pub fn release(&mut self, id: RegionId) -> Result<()> {
        let region = self
            .mgr
            .region(id)
            .ok_or_else(|| Error::Alloc(format!("probe release of unknown region {id}")))?;
        for r in coalesce(&region.glb) {
            self.glb.release(&r);
        }
        for r in coalesce(&region.array) {
            self.array.release(&r);
        }
        self.active -= 1;
        Ok(())
    }

    /// [`RegionManager::can_fit_now`] evaluated against the scratch
    /// state.
    pub fn can_fit_now(&self, demand: &SliceDemand) -> bool {
        self.mgr.fits_on(&self.glb, &self.array, self.active == 0, demand)
    }
}

/// Free slices of `map` lying in free runs of at least `min_run`.
/// Reads the incrementally maintained run index — this walk happens
/// once per event when energy accounting is on, so it must not allocate.
fn gated_count(map: &SliceMap, min_run: u32) -> u32 {
    map.free_runs_ref()
        .iter()
        .filter(|r| r.len >= min_run)
        .map(|r| r.len)
        .sum()
}

/// Slices of `ranges` that are currently gated in `map` (free runs of
/// at least `min_run`) — what an allocation over them must wake.
fn gated_overlap(map: &SliceMap, ranges: &[SliceRange], min_run: u32) -> u32 {
    let mut woken = 0;
    for run in map.free_runs_ref().iter().copied() {
        if run.len < min_run {
            continue;
        }
        for r in ranges {
            if r.overlaps(&run) {
                let lo = r.start.max(run.start);
                let hi = r.end().min(run.end());
                woken += hi - lo;
            }
        }
    }
    woken
}

/// Merge adjacent/overlapping ranges into maximal sorted runs.
fn coalesce(ranges: &[SliceRange]) -> Vec<SliceRange> {
    let mut sorted: Vec<SliceRange> =
        ranges.iter().copied().filter(|r| !r.is_empty()).collect();
    sorted.sort_by_key(|r| r.start);
    let mut out: Vec<SliceRange> = Vec::with_capacity(sorted.len());
    for r in sorted {
        match out.last_mut() {
            Some(last) if r.start <= last.end() => {
                last.len = last.len.max(r.end() - last.start);
            }
            _ => out.push(r),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mgr(policy: RegionPolicyKind) -> RegionManager {
        let arch = ArchConfig::default(); // 32 GLB slices, 8 array slices
        let sched = SchedulerConfig {
            region_policy: policy,
            unit_glb_slices: 4,
            unit_array_slices: 1,
            ..SchedulerConfig::default()
        };
        RegionManager::new(&arch, &sched)
    }

    // --------------------------------------------------------- baseline

    #[test]
    fn baseline_serializes_tasks() {
        let mut m = mgr(RegionPolicyKind::Baseline);
        let d = SliceDemand::new(7, 2);
        let r1 = m.try_allocate(&d).expect_allocated("first task");
        // whole machine taken regardless of demand
        assert_eq!(r1.footprint(), SliceDemand::new(32, 8));
        assert_eq!(m.try_allocate(&d), AllocOutcome::NoFit);
        m.release(r1.id).unwrap();
        m.try_allocate(&d).expect_allocated("after release");
    }

    #[test]
    fn baseline_rejects_oversized() {
        let mut m = mgr(RegionPolicyKind::Baseline);
        assert_eq!(m.try_allocate(&SliceDemand::new(33, 2)), AllocOutcome::NeverFits);
    }

    // --------------------------------------------------------- fixed

    #[test]
    fn fixed_carves_eight_units() {
        let m = mgr(RegionPolicyKind::FixedSize);
        assert_eq!(m.unit_count(), 8);
    }

    #[test]
    fn fixed_rejects_demand_larger_than_unit() {
        let mut m = mgr(RegionPolicyKind::FixedSize);
        assert_eq!(m.try_allocate(&SliceDemand::new(7, 1)), AllocOutcome::NeverFits);
        assert_eq!(m.try_allocate(&SliceDemand::new(4, 2)), AllocOutcome::NeverFits);
    }

    #[test]
    fn fixed_allocates_units_until_exhausted() {
        let mut m = mgr(RegionPolicyKind::FixedSize);
        let d = SliceDemand::new(4, 1);
        for _ in 0..8 {
            m.try_allocate(&d).expect_allocated("unit");
        }
        assert_eq!(m.try_allocate(&d), AllocOutcome::NoFit);
        let (ug, ua) = m.utilization();
        assert_eq!((ug, ua), (1.0, 1.0));
    }

    #[test]
    fn fixed_replication_takes_free_units() {
        let mut m = mgr(RegionPolicyKind::FixedSize);
        let d = SliceDemand::new(2, 1);
        let r = m.try_allocate_replicated(&d, 3).expect_allocated("unroll x3");
        assert_eq!(r.replicas, 3);
        // each replica owns a whole unit
        assert_eq!(r.footprint(), SliceDemand::new(12, 3));
        let r2 = m.try_allocate_replicated(&d, 100).expect_allocated("rest");
        assert_eq!(r2.replicas, 5);
        assert_eq!(m.try_allocate(&d), AllocOutcome::NoFit);
    }

    #[test]
    fn fixed_exclusive_fallback_needs_idle() {
        let mut m = mgr(RegionPolicyKind::FixedSize);
        let big = SliceDemand::new(20, 2); // conv5_x: fits no unit
        assert_eq!(m.try_allocate(&big), AllocOutcome::NeverFits);
        let r = m.try_allocate_exclusive(&big).expect_allocated("exclusive");
        assert_eq!(r.footprint(), SliceDemand::new(32, 8));
        assert_eq!(m.try_allocate(&SliceDemand::new(2, 1)), AllocOutcome::NoFit);
        m.release(r.id).unwrap();
        m.try_allocate(&SliceDemand::new(2, 1)).expect_allocated("unit after");
    }

    // --------------------------------------------------------- variable

    #[test]
    fn variable_merges_adjacent_units() {
        let mut m = mgr(RegionPolicyKind::VariableSize);
        // conv2_x b: 7 GLB + 6 array ⇒ k = max(ceil(7/4), ceil(6/1)) = 6
        let d = SliceDemand::new(7, 6);
        assert_eq!(m.units_needed(&d), 6);
        let r = m.try_allocate(&d).expect_allocated("merged");
        // merged region keeps the unit ratio: 6 units = 24 GLB + 6 array
        assert_eq!(r.footprint(), SliceDemand::new(24, 6));
        assert!(r.is_contiguous());
    }

    #[test]
    fn variable_internal_fragmentation_is_real() {
        // The paper's critique of variable-size (§2.3): GLB:array ratio is
        // fixed, so a GLB-heavy task wastes array slices.  Harris c needs
        // 14 GLB + 7 array ⇒ k=7 under (4,1) units ⇒ 28 GLB slices held.
        let mut m = mgr(RegionPolicyKind::VariableSize);
        let d = SliceDemand::new(14, 7);
        let r = m.try_allocate(&d).expect_allocated("harris c");
        assert_eq!(r.footprint(), SliceDemand::new(28, 7));
        // ...leaving no room for camera b (14 GLB + 6 array ⇒ k=6)
        assert_eq!(m.try_allocate(&SliceDemand::new(14, 6)), AllocOutcome::NoFit);
    }

    #[test]
    fn variable_adjacency_constraint() {
        let mut m = mgr(RegionPolicyKind::VariableSize);
        let unit = SliceDemand::new(4, 1);
        // occupy units 0,1 then 3 — leaving 2 and 4..8 free
        let a = m.try_allocate(&SliceDemand::new(8, 2)).expect_allocated("u01");
        let _b = m.try_allocate(&unit).expect_allocated("u2");
        let c = m.try_allocate(&unit).expect_allocated("u3");
        m.release(_b.id).unwrap();
        // need 4 adjacent units: only 4..8 qualifies (2 is isolated)
        let big = m.try_allocate(&SliceDemand::new(16, 4)).expect_allocated("u4..8");
        assert_eq!(big.array[0], SliceRange::new(4, 4));
        m.release(a.id).unwrap();
        m.release(c.id).unwrap();
        assert_eq!(m.active_count(), 1);
    }

    #[test]
    fn variable_never_fits_when_over_machine() {
        let mut m = mgr(RegionPolicyKind::VariableSize);
        // 9 array slices would need 9 units > 8
        assert_eq!(m.try_allocate(&SliceDemand::new(4, 9)), AllocOutcome::NeverFits);
    }

    // --------------------------------------------------------- flexible

    #[test]
    fn flexible_allocates_exact_demand() {
        let mut m = mgr(RegionPolicyKind::FlexibleShape);
        let d = SliceDemand::new(7, 2);
        let r = m.try_allocate(&d).expect_allocated("conv2_x a");
        assert_eq!(r.footprint(), d);
        let (ug, ua) = m.utilization();
        assert!((ug - 7.0 / 32.0).abs() < 1e-12);
        assert!((ua - 2.0 / 8.0).abs() < 1e-12);
    }

    #[test]
    fn flexible_decouples_glb_and_array() {
        let mut m = mgr(RegionPolicyKind::FlexibleShape);
        // GLB-heavy + array-heavy coexist: conv5_x a (20g,2a) + harris b (7g,4a)
        let r1 = m.try_allocate(&SliceDemand::new(20, 2)).expect_allocated("conv5 a");
        let r2 = m.try_allocate(&SliceDemand::new(7, 4)).expect_allocated("harris b");
        assert_eq!(m.active_count(), 2);
        assert!(!r1.array[0].overlaps(&r2.array[0]));
        assert!(!r1.glb[0].overlaps(&r2.glb[0]));
        // the same pair can NOT coexist under variable-size (4,1) units:
        // conv5a needs k=5 (20 glb), harris b needs k=4 ⇒ 9 units > 8.
    }

    #[test]
    fn flexible_prefers_colocated_glb() {
        let mut m = mgr(RegionPolicyKind::FlexibleShape);
        // Occupy array slices 0..2 and glb 0..8 first.
        let _r1 = m.try_allocate(&SliceDemand::new(8, 2)).expect_allocated("first");
        // Next region gets array 2..4; preferred GLB start = 2*4 = 8.
        let r2 = m.try_allocate(&SliceDemand::new(4, 2)).expect_allocated("second");
        assert_eq!(r2.array[0], SliceRange::new(2, 2));
        assert_eq!(r2.glb[0], SliceRange::new(8, 4));
    }

    #[test]
    fn flexible_no_fit_vs_never_fits() {
        let mut m = mgr(RegionPolicyKind::FlexibleShape);
        let _ = m.try_allocate(&SliceDemand::new(30, 7)).expect_allocated("hog");
        assert_eq!(m.try_allocate(&SliceDemand::new(4, 2)), AllocOutcome::NoFit);
        assert_eq!(m.try_allocate(&SliceDemand::new(33, 1)), AllocOutcome::NeverFits);
    }

    // --------------------------------------------------------- common

    #[test]
    fn release_unknown_region_errors() {
        let mut m = mgr(RegionPolicyKind::FlexibleShape);
        assert!(m.release(RegionId(99)).is_err());
    }

    #[test]
    fn render_shows_occupancy() {
        let mut m = mgr(RegionPolicyKind::FlexibleShape);
        let _ = m.try_allocate(&SliceDemand::new(2, 1)).expect_allocated("r");
        let dump = m.render();
        assert!(dump.contains("GLB   ##"));
        assert!(dump.contains("ARRAY #"));
    }

    #[test]
    fn coalesce_merges_adjacent_runs() {
        let merged = coalesce(&[
            SliceRange::new(4, 2),
            SliceRange::new(0, 2),
            SliceRange::new(2, 2),
            SliceRange::new(8, 1),
            SliceRange::empty(),
        ]);
        assert_eq!(merged, vec![SliceRange::new(0, 6), SliceRange::new(8, 1)]);
        assert_eq!(coalesce(&[]), Vec::<SliceRange>::new());
    }

    #[test]
    fn release_coalesces_replicated_unit_ranges_eagerly() {
        // A task replicated into 3 *adjacent* fixed-size units owns three
        // ranges forming one physical run; releasing it must leave the
        // free list canonical (one maximal run), which the planner and
        // the fragmentation gauge rely on.
        let mut m = mgr(RegionPolicyKind::FixedSize);
        let r = m
            .try_allocate_replicated(&SliceDemand::new(2, 1), 3)
            .expect_allocated("unroll x3");
        assert_eq!(r.glb.len(), 3);
        m.release(r.id).unwrap();
        assert_eq!(m.glb_map().free_runs(), vec![SliceRange::new(0, 32)]);
        assert_eq!(m.array_map().free_runs(), vec![SliceRange::new(0, 8)]);
        assert_eq!(m.fragmentation(), (0.0, 0.0));
    }

    #[test]
    fn relocate_moves_a_flexible_region() {
        let mut m = mgr(RegionPolicyKind::FlexibleShape);
        let a = m.try_allocate(&SliceDemand::new(4, 2)).expect_allocated("a");
        let b = m.try_allocate(&SliceDemand::new(4, 2)).expect_allocated("b");
        m.release(a.id).unwrap();
        // b sits at array [2..4), glb [8..12); compact it to the origin
        m.relocate(b.id, Some(SliceRange::new(0, 4)), Some(SliceRange::new(0, 2)))
            .unwrap();
        let moved = m.region(b.id).unwrap();
        assert_eq!(moved.glb, vec![SliceRange::new(0, 4)]);
        assert_eq!(moved.array, vec![SliceRange::new(0, 2)]);
        assert_eq!(m.fragmentation(), (0.0, 0.0));
        // occupancy conserved
        assert_eq!(m.glb_map().busy_count(), 4);
        assert_eq!(m.array_map().busy_count(), 2);
    }

    #[test]
    fn relocate_handles_self_overlapping_shift() {
        let mut m = mgr(RegionPolicyKind::FlexibleShape);
        let a = m.try_allocate(&SliceDemand::new(8, 4)).expect_allocated("a");
        // shift left by 2 array slices over its own footprint: impossible
        // at allocation time, fine for relocation
        let pad = m.try_allocate(&SliceDemand::new(2, 1)).expect_allocated("pad");
        m.release(pad.id).unwrap();
        m.relocate(a.id, Some(SliceRange::new(2, 8)), Some(SliceRange::new(1, 4)))
            .unwrap();
        assert_eq!(m.region(a.id).unwrap().array, vec![SliceRange::new(1, 4)]);
        assert_eq!(m.glb_map().busy_count(), 8);
    }

    #[test]
    fn relocate_rejects_bad_targets_without_mutating() {
        let mut m = mgr(RegionPolicyKind::FlexibleShape);
        let a = m.try_allocate(&SliceDemand::new(4, 2)).expect_allocated("a");
        let b = m.try_allocate(&SliceDemand::new(4, 2)).expect_allocated("b");
        let before = m.render();
        // unknown region
        assert!(m.relocate(RegionId(99), None, None).is_err());
        // length change
        assert!(m
            .relocate(a.id, Some(SliceRange::new(8, 6)), None)
            .is_err());
        // out of bounds
        assert!(m
            .relocate(a.id, None, Some(SliceRange::new(7, 2)))
            .is_err());
        // target busy (b's slices)
        let b_arr = b.array[0];
        assert!(m.relocate(a.id, None, Some(b_arr)).is_err());
        assert_eq!(m.render(), before, "failed relocation must not mutate");
    }

    #[test]
    fn relocate_rejects_replicated_regions() {
        let mut m = mgr(RegionPolicyKind::FixedSize);
        let small = SliceDemand::new(2, 1);
        let r = m.try_allocate_replicated(&small, 2).expect_allocated("x2");
        // skip a unit so the region is genuinely multi-range
        assert!(r.glb.len() >= 2);
        assert!(m.relocate(r.id, None, None).is_err());
    }

    #[test]
    fn can_fit_now_tracks_try_allocate_without_mutating() {
        for policy in RegionPolicyKind::ALL {
            let mut m = mgr(policy);
            let d = SliceDemand::new(4, 1);
            // empty machine: probe agrees with a real allocation...
            let before = m.render();
            assert!(m.can_fit_now(&d), "{policy:?}");
            assert_eq!(m.render(), before, "probe must not mutate");
            // ...and after filling the machine the probe flips to false
            // exactly when try_allocate stops yielding regions.
            let mut n = 0;
            while let AllocOutcome::Allocated(_) = m.try_allocate(&d) {
                n += 1;
                assert!(n <= 64, "runaway allocation under {policy:?}");
            }
            assert!(!m.can_fit_now(&d), "{policy:?} full but probe says fit");
            // oversized demands are never claimed to fit
            assert!(!m.can_fit_now(&SliceDemand::new(33, 9)), "{policy:?}");
        }
    }

    // ---------------------------------------------------------- gating

    #[test]
    fn gating_off_reports_nothing_and_wakes_nothing() {
        let mut m = mgr(RegionPolicyKind::FlexibleShape);
        assert!(!m.gating_enabled());
        assert_eq!(m.gated_counts(), (0, 0));
        let r = m.try_allocate(&SliceDemand::new(4, 2)).expect_allocated("r");
        assert_eq!(r.woken(), (0, 0));
    }

    #[test]
    fn fresh_fabric_is_fully_gated_and_allocations_wake_it() {
        let mut m = mgr(RegionPolicyKind::FlexibleShape);
        m.set_gating(true, 4);
        assert_eq!(m.gated_counts(), (32, 8), "whole-fabric free runs gate");
        assert_eq!(m.idle_free_counts(), (0, 0));
        let r = m.try_allocate(&SliceDemand::new(4, 2)).expect_allocated("r");
        assert_eq!(r.woken(), (4, 2), "allocation woke its slices");
        // remaining free runs are still ≥ 4 slices: still gated
        assert_eq!(m.gated_counts(), (28, 6));
    }

    #[test]
    fn fragmentation_holes_below_min_run_stay_awake() {
        // Four 2-slice tasks fill the array; freeing the 2nd and 4th
        // leaves free runs {2,3} and {6,7} — both shorter than
        // gate_min_run, so those four slices burn idle power.
        let mut m = mgr(RegionPolicyKind::FlexibleShape);
        m.set_gating(true, 4);
        let d = SliceDemand::new(4, 2);
        let rs: Vec<_> =
            (0..4).map(|_| m.try_allocate(&d).expect_allocated("fill")).collect();
        m.release(rs[1].id).unwrap();
        m.release(rs[3].id).unwrap();
        let (_, gated_arr) = m.gated_counts();
        assert_eq!(gated_arr, 0, "scattered 2-slice holes cannot gate");
        assert_eq!(m.idle_free_counts().1, 4);
        // compacting the survivors merges the holes into one gated run
        m.relocate(rs[2].id, Some(SliceRange::new(4, 4)), Some(SliceRange::new(2, 2)))
            .unwrap();
        assert_eq!(m.gated_counts().1, 4, "defragmentation earns the watts back");
        assert_eq!(m.idle_free_counts().1, 0);
    }

    #[test]
    fn release_regates_merged_runs() {
        let mut m = mgr(RegionPolicyKind::FlexibleShape);
        m.set_gating(true, 4);
        let a = m.try_allocate(&SliceDemand::new(16, 4)).expect_allocated("a");
        assert_eq!(m.gated_counts(), (16, 4));
        m.release(a.id).unwrap();
        assert_eq!(m.gated_counts(), (32, 8), "vacated slices re-gate");
    }

    // ------------------------------------------------------------- noc

    fn noc_mgr(comm_aware: bool) -> RegionManager {
        let arch = ArchConfig::default();
        let mut m = mgr(RegionPolicyKind::FlexibleShape);
        m.set_noc(&arch, comm_aware);
        m
    }

    #[test]
    fn noc_off_reports_nothing() {
        let m = mgr(RegionPolicyKind::FlexibleShape);
        assert!(!m.noc_enabled());
        assert!(m.corridor_map().is_none());
        assert_eq!(m.corridor_pressure(), 0.0);
        assert_eq!(m.corridor_slowdown(RegionId(0)), 1.0);
    }

    #[test]
    fn corridors_track_region_lifecycle() {
        let mut m = noc_mgr(false);
        let r = m.try_allocate(&SliceDemand::new(8, 2)).expect_allocated("r");
        let span = m.corridor_span(r.id);
        assert!(!span.is_empty());
        assert_eq!(span.tracks, 8);
        let map = m.corridor_map().unwrap();
        assert_eq!(map.total_demand(), span.range.len as u64 * 8);
        m.release(r.id).unwrap();
        assert!(m.corridor_map().unwrap().is_idle(), "release returns corridor demand");
    }

    #[test]
    fn relocation_moves_corridor_demand() {
        let mut m = noc_mgr(false);
        let a = m.try_allocate(&SliceDemand::new(4, 2)).expect_allocated("a");
        let b = m.try_allocate(&SliceDemand::new(4, 2)).expect_allocated("b");
        m.release(a.id).unwrap();
        let before = m.corridor_map().unwrap().total_demand();
        m.relocate(b.id, Some(SliceRange::new(0, 4)), Some(SliceRange::new(0, 2)))
            .unwrap();
        let map = m.corridor_map().unwrap();
        assert_eq!(map.total_demand(), before, "demand conserved across the move");
        assert_eq!(map.demand(0), 4, "demand followed the region to the origin");
        m.release(b.id).unwrap();
        assert!(m.corridor_map().unwrap().is_idle());
    }

    #[test]
    fn slowdown_reflects_oversubscription() {
        let mut m = noc_mgr(false);
        // Two 14-bank regions forced onto overlapping corridors: the
        // second lands its GLB wherever it fits, widening its span.
        let a = m.try_allocate(&SliceDemand::new(14, 1)).expect_allocated("a");
        let b = m.try_allocate(&SliceDemand::new(14, 1)).expect_allocated("b");
        let worst = m.corridor_slowdown(a.id).max(m.corridor_slowdown(b.id));
        assert!(worst > 1.0, "28 demanded tracks over 20 must contend, got {worst}");
        assert!(m.corridor_pressure() > 1.0);
        assert!(m.corridor_peak_oversub() > 1.0);
    }

    #[test]
    fn comm_aware_placement_dodges_hot_corridors() {
        // Oblivious: both regions' GLB runs pile left → overlap.
        let mut obl = noc_mgr(false);
        let o1 = obl.try_allocate(&SliceDemand::new(14, 1)).expect_allocated("o1");
        let o2 = obl.try_allocate(&SliceDemand::new(14, 1)).expect_allocated("o2");
        let obl_worst = obl.corridor_slowdown(o1.id).max(obl.corridor_slowdown(o2.id));
        // Comm-aware: the second region picks an array run whose
        // aligned GLB corridors are still cold.
        let mut aware = noc_mgr(true);
        let a1 = aware.try_allocate(&SliceDemand::new(14, 1)).expect_allocated("a1");
        let a2 = aware.try_allocate(&SliceDemand::new(14, 1)).expect_allocated("a2");
        let aware_worst = aware.corridor_slowdown(a1.id).max(aware.corridor_slowdown(a2.id));
        assert!(
            aware_worst < obl_worst,
            "comm-aware ({aware_worst}) must beat oblivious ({obl_worst})"
        );
        assert_eq!(aware.corridor_slowdown(a2.id), 1.0, "second region fully dodged");
    }

    #[test]
    fn placement_hint_pulls_region_toward_producer() {
        let mut m = noc_mgr(true);
        // Uncontended fabric: the hint is the only differentiator.
        let r = m
            .try_allocate_hinted(&SliceDemand::new(4, 2), Some(5))
            .expect_allocated("hinted");
        assert_eq!(r.array[0].start, 5, "consumer lands on the producer's slices");
        // Without comm-aware NoC the hint is ignored.
        let mut plain = mgr(RegionPolicyKind::FlexibleShape);
        let p = plain
            .try_allocate_hinted(&SliceDemand::new(4, 2), Some(5))
            .expect_allocated("plain");
        assert_eq!(p.array[0].start, 0, "pre-NoC first-fit unchanged");
    }

    #[test]
    fn comm_aware_agrees_with_first_fit_on_feasibility() {
        // Fill the fabric under both flavors: same number of regions fit.
        for aware in [false, true] {
            let mut m = noc_mgr(aware);
            let d = SliceDemand::new(4, 1);
            let mut n = 0;
            while let AllocOutcome::Allocated(_) = m.try_allocate(&d) {
                n += 1;
                assert!(n <= 64, "runaway");
            }
            assert_eq!(n, 8, "aware={aware}");
            assert_eq!(m.try_allocate(&d), AllocOutcome::NoFit);
        }
    }

    #[test]
    fn can_ever_fit_matrix() {
        let conv5a = SliceDemand::new(20, 2);
        assert!(mgr(RegionPolicyKind::Baseline).can_ever_fit(&conv5a));
        assert!(!mgr(RegionPolicyKind::FixedSize).can_ever_fit(&conv5a));
        assert!(mgr(RegionPolicyKind::VariableSize).can_ever_fit(&conv5a)); // k=5 ≤ 8
        assert!(mgr(RegionPolicyKind::FlexibleShape).can_ever_fit(&conv5a));
    }
}
