//! Execution-region value types.

use std::fmt;

use crate::abstraction::{SliceDemand, SliceRange};

/// Opaque region handle.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct RegionId(pub u64);

impl fmt::Display for RegionId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "R{}", self.0)
    }
}

/// One allocated execution region.
///
/// Under the fixed-size mechanism a region may span several disjoint unit
/// ranges (a task replicated into k units, Fig. 2b); the other mechanisms
/// always allocate a single contiguous range per slice class.
#[derive(Clone, Debug, PartialEq)]
pub struct ExecutionRegion {
    /// Handle.
    pub id: RegionId,
    /// GLB-slice ranges owned by the region.
    pub glb: Vec<SliceRange>,
    /// Array-slice ranges owned by the region.
    pub array: Vec<SliceRange>,
    /// Replication factor: number of independent task copies mapped
    /// (1 except for fixed-size unrolling).
    pub replicas: u32,
    /// GLB slices that were power-gated when this region was committed
    /// (the allocation woke them; 0 unless gating is enabled).
    pub woken_glb: u32,
    /// Array slices the allocation woke (see `woken_glb`).
    pub woken_array: u32,
}

impl ExecutionRegion {
    /// Total GLB slices owned.
    pub fn glb_slices(&self) -> u32 {
        self.glb.iter().map(|r| r.len).sum()
    }

    /// Total array slices owned.
    pub fn array_slices(&self) -> u32 {
        self.array.iter().map(|r| r.len).sum()
    }

    /// Owned resources as a demand vector (for accounting).
    pub fn footprint(&self) -> SliceDemand {
        SliceDemand::new(self.glb_slices(), self.array_slices())
    }

    /// Whether the region's ranges are each contiguous single runs.
    pub fn is_contiguous(&self) -> bool {
        self.glb.len() <= 1 && self.array.len() <= 1
    }

    /// Slices the allocation woke from power gating, `(glb, array)`.
    pub fn woken(&self) -> (u32, u32) {
        (self.woken_glb, self.woken_array)
    }
}

impl fmt::Display for ExecutionRegion {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{} glb", self.id)?;
        for r in &self.glb {
            write!(f, "{r}")?;
        }
        write!(f, " arr")?;
        for r in &self.array {
            write!(f, "{r}")?;
        }
        if self.replicas > 1 {
            write!(f, " x{}", self.replicas)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn footprint_sums_ranges() {
        let r = ExecutionRegion {
            id: RegionId(1),
            glb: vec![SliceRange::new(0, 2), SliceRange::new(4, 2)],
            array: vec![SliceRange::new(0, 1)],
            replicas: 2,
            woken_glb: 0,
            woken_array: 0,
        };
        assert_eq!(r.glb_slices(), 4);
        assert_eq!(r.array_slices(), 1);
        assert_eq!(r.footprint(), SliceDemand::new(4, 1));
        assert!(!r.is_contiguous());
    }

    #[test]
    fn display_is_compact() {
        let r = ExecutionRegion {
            id: RegionId(3),
            glb: vec![SliceRange::new(0, 2)],
            array: vec![SliceRange::new(2, 1)],
            replicas: 1,
            woken_glb: 0,
            woken_array: 0,
        };
        assert_eq!(r.to_string(), "R3 glb[0..2) arr[2..3)");
    }
}
