//! Energy accounting, power-gated slices, and the power-cap governor.
//!
//! The paper's abstractions exist so a scheduler can "reason about
//! performance, energy, and utilization for different schedules" (§1);
//! this module supplies the missing energy axis:
//!
//! * [`EnergyModel`] — per-cycle active/idle/gated costs for PE tiles,
//!   MEM tiles and GLB banks (stream-port activity derived from
//!   bandwidth), per-bit DPR stream energy, and migration copy energy,
//!   parameterized by the `[energy]` TOML section
//!   ([`crate::config::EnergyConfig`], Amber-derived defaults).
//! * [`EnergyAccountant`] — integrates power over the simulation clock
//!   into per-task, per-tenant and per-shard joule counters
//!   ([`EnergyReport`]), and doubles as the **power-cap governor**: with
//!   `energy.power_cap_watts` set it refuses launches that would push
//!   the fabric past the cap, so the windowed average power stays below
//!   it (the `BENCH_energy.json` acceptance bar).
//!
//! Power gating itself lives in [`crate::regions::RegionManager`]: a
//! free slice is gated when its maximal free run reaches
//! `energy.gate_min_run` slices, so scattered fragmentation holes stay
//! awake at idle power — fragmentation costs watts, and the
//! defragmentation subsystem ([`crate::migration`]) earns them back.
//! Waking a gated domain charges `energy.wake_cycles` to the launch,
//! exactly like DPR cycles.
//!
//! With `[energy]` absent (`enabled = false`, the default) every path
//! here is inert and all pre-existing reports and traces are
//! bit-for-bit unchanged.

mod meter;
mod model;

pub use meter::{EnergyAccountant, EnergyReport};
pub use model::{ActivePower, EnergyModel, PJ_TO_J};
