//! Per-component energy/power model.
//!
//! Everything is priced in **picojoules per core-clock cycle** and
//! converted to watts/joules only at reporting boundaries: pJ/cycle ×
//! clock (cycles/s) × 10⁻¹² = watts, and accumulated pJ × 10⁻¹² =
//! joules.  Keeping the integrator in pJ/cycle makes the accounting an
//! exact piecewise-constant sum over the discrete-event clock — the
//! energy-conservation property in `tests/prop_energy.rs` holds to
//! floating-point round-off, and repeat runs are byte-identical.

use crate::abstraction::{RawUsage, SliceDemand};
use crate::config::{ArchConfig, EnergyConfig};

/// Joules per picojoule.
pub const PJ_TO_J: f64 = 1e-12;

/// Active-power breakdown of one allocated region, pJ/cycle.
///
/// Split per component so the accountant can integrate PE, MEM and GLB
/// energy into separate conservation-checked counters.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct ActivePower {
    /// PE tiles computing.
    pub pe_pj: f64,
    /// MEM tiles computing.
    pub mem_pj: f64,
    /// GLB banks held: retention + stream-port switching.
    pub glb_pj: f64,
    /// Slices held by the region beyond the variant's demand (exclusive
    /// and replicated allocations over-hold), burning idle power.
    pub held_idle_pj: f64,
}

impl ActivePower {
    /// Total pJ/cycle.
    pub fn total(&self) -> f64 {
        self.pe_pj + self.mem_pj + self.glb_pj + self.held_idle_pj
    }
}

/// The per-component energy model, pre-resolved against an architecture.
#[derive(Clone, Debug)]
pub struct EnergyModel {
    cfg: EnergyConfig,
    /// PE tiles per array-slice.
    pe_per_slice: u32,
    /// MEM tiles per array-slice.
    mem_per_slice: u32,
    /// Peak stream bytes/cycle per GLB bank.
    bank_bytes_per_cycle: u32,
    /// Core clock, MHz (watt conversions).
    clock_mhz: u32,
}

impl EnergyModel {
    /// Resolve `cfg` against the architecture geometry.
    pub fn new(arch: &ArchConfig, cfg: &EnergyConfig) -> EnergyModel {
        EnergyModel {
            cfg: cfg.clone(),
            pe_per_slice: arch.pe_tiles_per_slice(),
            mem_per_slice: arch.mem_tiles_per_slice(),
            bank_bytes_per_cycle: arch.glb_bank_bytes_per_cycle,
            clock_mhz: arch.core_clock_mhz,
        }
    }

    /// The configuration this model was built from.
    pub fn config(&self) -> &EnergyConfig {
        &self.cfg
    }

    /// Core clock in MHz.
    pub fn clock_mhz(&self) -> u32 {
        self.clock_mhz
    }

    /// Convert a pJ/cycle rate into watts at the core clock.
    pub fn pj_per_cycle_to_watts(&self, pj: f64) -> f64 {
        pj * self.clock_mhz as f64 * 1e6 * PJ_TO_J
    }

    /// One awake-but-unallocated array-slice, pJ/cycle.
    pub fn array_slice_idle_pj(&self) -> f64 {
        self.pe_per_slice as f64 * self.cfg.pe_idle_pj
            + self.mem_per_slice as f64 * self.cfg.mem_idle_pj
    }

    /// One power-gated array-slice, pJ/cycle.
    pub fn array_slice_gated_pj(&self) -> f64 {
        (self.pe_per_slice + self.mem_per_slice) as f64 * self.cfg.tile_gated_pj
    }

    /// One awake-but-unallocated GLB bank, pJ/cycle.
    pub fn glb_slice_idle_pj(&self) -> f64 {
        self.cfg.glb_idle_pj
    }

    /// One power-gated GLB bank, pJ/cycle.
    pub fn glb_slice_gated_pj(&self) -> f64 {
        self.cfg.glb_gated_pj
    }

    /// One computing array-slice, pJ/cycle.
    pub fn array_slice_active_pj(&self) -> f64 {
        self.pe_per_slice as f64 * self.cfg.pe_active_pj
            + self.mem_per_slice as f64 * self.cfg.mem_active_pj
    }

    /// One held GLB bank streaming `bytes_per_cycle`, pJ/cycle.
    pub fn glb_slice_active_pj(&self, bytes_per_cycle: f64) -> f64 {
        self.cfg.glb_active_pj + bytes_per_cycle * self.cfg.glb_stream_pj_per_byte
    }

    /// Stream rate an active bank is assumed to sustain when only slice
    /// counts are known (Table 1 rows): peak port bandwidth × duty.
    pub fn assumed_bank_bytes_per_cycle(&self) -> f64 {
        self.bank_bytes_per_cycle as f64 * self.cfg.stream_duty
    }

    /// Active power of a computing region: `demand` slices computing,
    /// plus `held` − `demand` slices held at idle rates (`held` is the
    /// region footprint; exclusive/replicated allocations over-hold).
    pub fn region_power(&self, demand: &SliceDemand, held: &SliceDemand) -> ActivePower {
        self.region_power_scaled(demand, held, 1.0)
    }

    /// [`Self::region_power`] with the assumed stream duty scaled by
    /// `duty_scale` — the NoC contention path ([`crate::noc`]): a region
    /// whose corridors are oversubscribed streams at a fraction of the
    /// assumed port bandwidth, so its GLB stream energy per cycle drops
    /// by the same factor (the cycles stretch instead).  `duty_scale`
    /// of 1.0 reproduces [`Self::region_power`] bit-for-bit.
    pub fn region_power_scaled(
        &self,
        demand: &SliceDemand,
        held: &SliceDemand,
        duty_scale: f64,
    ) -> ActivePower {
        let bank_bw = self.assumed_bank_bytes_per_cycle() * duty_scale;
        let held_glb = held.glb_slices.saturating_sub(demand.glb_slices);
        let held_arr = held.array_slices.saturating_sub(demand.array_slices);
        ActivePower {
            pe_pj: demand.array_slices as f64 * self.pe_per_slice as f64 * self.cfg.pe_active_pj,
            mem_pj: demand.array_slices as f64
                * self.mem_per_slice as f64
                * self.cfg.mem_active_pj,
            glb_pj: demand.glb_slices as f64 * self.glb_slice_active_pj(bank_bw),
            held_idle_pj: held_arr as f64 * self.array_slice_idle_pj()
                + held_glb as f64 * self.glb_slice_idle_pj(),
        }
    }

    /// Power a raw (un-quantized) usage draws, in watts — the
    /// bandwidth-derived stream-port activity path for demands that
    /// carry a measured [`RawUsage`] instead of Table 1 slice counts.
    pub fn usage_power_watts(&self, usage: &RawUsage, arch: &ArchConfig) -> f64 {
        let demand = usage.quantize(arch);
        // spread the measured bandwidth across the allocated banks
        let bytes_per_cycle = if demand.glb_slices > 0 {
            usage.glb_bw_bytes_per_sec
                / (arch.core_clock_mhz as f64 * 1e6)
                / demand.glb_slices as f64
        } else {
            0.0
        };
        let pj = demand.array_slices as f64 * self.array_slice_active_pj()
            + demand.glb_slices as f64 * self.glb_slice_active_pj(bytes_per_cycle);
        self.pj_per_cycle_to_watts(pj)
    }

    /// Configuration-stream energy of `words` 32-bit config words, pJ.
    /// A cache miss pays the host DMA pass on top of the GLB stream.
    pub fn dpr_stream_pj(&self, words: u64, cache_hit: bool) -> f64 {
        let passes = if cache_hit { 1.0 } else { 2.0 };
        words as f64 * 32.0 * self.cfg.dpr_pj_per_bit * passes
    }

    /// Migration-step energy: restream `restream_bits` of configuration
    /// plus copy `glb_bytes_moved` bank-to-bank, pJ.
    pub fn migration_step_pj(&self, restream_bits: u64, glb_bytes_moved: u64) -> f64 {
        restream_bits as f64 * self.cfg.dpr_pj_per_bit
            + glb_bytes_moved as f64 * self.cfg.glb_stream_pj_per_byte
    }

    /// One-shot wake energy of bringing gated domains up: the woken
    /// domains burn idle power for the wake handshake.
    pub fn wake_pj(&self, woken_glb: u32, woken_array: u32) -> f64 {
        self.cfg.wake_cycles as f64
            * (woken_array as f64 * self.array_slice_idle_pj()
                + woken_glb as f64 * self.glb_slice_idle_pj())
    }

    /// Fabric overhead pJ/cycle: deep sleep when fully drained, static
    /// otherwise.
    pub fn fabric_overhead_pj(&self, any_region_active: bool) -> f64 {
        if any_region_active {
            self.cfg.fabric_static_pj
        } else {
            self.cfg.fabric_sleep_pj
        }
    }

    /// Power-cap in pJ/cycle (`None` when uncapped).
    pub fn cap_pj_per_cycle(&self) -> Option<f64> {
        if self.cfg.power_cap_watts > 0.0 {
            Some(self.cfg.power_cap_watts / (self.clock_mhz as f64 * 1e6 * PJ_TO_J))
        } else {
            None
        }
    }

    /// Marginal pJ/cycle the fabric would *add* by hosting `demand`,
    /// given its current awake-idle and gated free-slice counts and
    /// whether it is currently drained (deep sleep).  Energy-aware pool
    /// placement minimizes this.
    pub fn marginal_placement_pj(
        &self,
        demand: &SliceDemand,
        idle_free: (u32, u32),
        drained: bool,
    ) -> f64 {
        let power = self.region_power(demand, demand);
        // slices taken from the awake-idle pool stop drawing idle power
        let reclaimed_glb = demand.glb_slices.min(idle_free.0) as f64 * self.glb_slice_idle_pj();
        let reclaimed_arr =
            demand.array_slices.min(idle_free.1) as f64 * self.array_slice_idle_pj();
        let fabric_wake = if drained {
            self.cfg.fabric_static_pj - self.cfg.fabric_sleep_pj
        } else {
            0.0
        };
        power.total() - reclaimed_glb - reclaimed_arr + fabric_wake
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> EnergyModel {
        EnergyModel::new(&ArchConfig::default(), &EnergyConfig::default())
    }

    #[test]
    fn default_fabric_lands_in_the_low_watt_range() {
        let m = model();
        // all 8 array slices + all 32 banks computing
        let full = SliceDemand::new(32, 8);
        let p = m.region_power(&full, &full);
        let watts =
            m.pj_per_cycle_to_watts(p.total() + m.fabric_overhead_pj(true));
        assert!((1.0..10.0).contains(&watts), "full-fabric power {watts} W");
        // idle floor is roughly a tenth of that
        let idle = 8.0 * m.array_slice_idle_pj() + 32.0 * m.glb_slice_idle_pj();
        let idle_w = m.pj_per_cycle_to_watts(idle + m.fabric_overhead_pj(true));
        assert!(idle_w < watts / 3.0, "idle {idle_w} vs active {watts}");
        // gated floor is far below idle
        let gated = 8.0 * m.array_slice_gated_pj() + 32.0 * m.glb_slice_gated_pj();
        let gated_w = m.pj_per_cycle_to_watts(gated + m.fabric_overhead_pj(false));
        assert!(gated_w < idle_w / 10.0, "gated {gated_w} vs idle {idle_w}");
    }

    #[test]
    fn region_power_charges_overheld_slices_at_idle() {
        let m = model();
        let demand = SliceDemand::new(4, 2);
        let exact = m.region_power(&demand, &demand);
        assert_eq!(exact.held_idle_pj, 0.0);
        let hog = m.region_power(&demand, &SliceDemand::new(32, 8));
        assert_eq!(hog.pe_pj, exact.pe_pj);
        assert!(hog.held_idle_pj > 0.0);
        let expect =
            6.0 * m.array_slice_idle_pj() + 28.0 * m.glb_slice_idle_pj();
        assert!((hog.held_idle_pj - expect).abs() < 1e-9);
    }

    #[test]
    fn usage_power_scales_with_bandwidth() {
        let m = model();
        let arch = ArchConfig::default();
        let slow = RawUsage {
            glb_bytes: 750 * 1024,
            glb_bw_bytes_per_sec: 1e6,
            pe_tiles: 80,
            mem_tiles: 17,
        };
        let fast = RawUsage { glb_bw_bytes_per_sec: 10e9, ..slow };
        assert!(m.usage_power_watts(&fast, &arch) > m.usage_power_watts(&slow, &arch));
    }

    #[test]
    fn dpr_miss_pays_double_stream_energy() {
        let m = model();
        assert_eq!(m.dpr_stream_pj(1000, false), 2.0 * m.dpr_stream_pj(1000, true));
    }

    #[test]
    fn cap_conversion_round_trips() {
        let cfg = EnergyConfig { power_cap_watts: 2.0, ..EnergyConfig::default() };
        let m = EnergyModel::new(&ArchConfig::default(), &cfg);
        let pj = m.cap_pj_per_cycle().unwrap();
        assert!((m.pj_per_cycle_to_watts(pj) - 2.0).abs() < 1e-12);
        assert!(model().cap_pj_per_cycle().is_none());
    }

    #[test]
    fn marginal_placement_prefers_awake_idle_over_drained() {
        let m = model();
        let d = SliceDemand::new(4, 2);
        let on_awake = m.marginal_placement_pj(&d, (4, 2), false);
        let on_gated = m.marginal_placement_pj(&d, (0, 0), false);
        let on_drained = m.marginal_placement_pj(&d, (0, 0), true);
        assert!(on_awake < on_gated, "{on_awake} vs {on_gated}");
        assert!(on_gated < on_drained, "{on_gated} vs {on_drained}");
    }
}
