//! The energy accountant: integrates power over the simulation clock.
//!
//! One [`EnergyAccountant`] lives inside each [`crate::scheduler::Scheduler`]
//! (so each fabric shard meters itself).  The scheduler calls
//! [`EnergyAccountant::advance`] at the top of every state-changing
//! entry point (schedule / complete / defrag), integrating the *previous*
//! power state over the elapsed cycles — power is piecewise-constant
//! between discrete events, so the integral is exact.
//!
//! The accountant doubles as the **power-cap governor**: with
//! `energy.power_cap_watts > 0` it refuses launches whose projected
//! draw would push the fabric over the cap ([`EnergyAccountant::admits`]),
//! which also bounds the windowed average the wire protocol reports.
//! A drained fabric always admits one task, so a cap below a single
//! task's draw degrades to serial execution instead of deadlocking.

use std::collections::{BTreeMap, VecDeque};

use crate::abstraction::SliceDemand;
use crate::regions::RegionId;

use super::model::{ActivePower, EnergyModel, PJ_TO_J};

/// Final energy accounting of one run (all values in joules).
#[derive(Clone, Debug, PartialEq)]
pub struct EnergyReport {
    /// Total energy.
    pub total_j: f64,
    /// PE tiles computing.
    pub pe_j: f64,
    /// MEM tiles computing.
    pub mem_j: f64,
    /// GLB banks held (retention + streaming).
    pub glb_j: f64,
    /// Awake-but-unallocated slices plus over-held region slices.
    pub idle_j: f64,
    /// Power-gated slices (leakage floor).
    pub gated_j: f64,
    /// Fabric overhead (static while hosting work, deep sleep drained).
    pub static_j: f64,
    /// Configuration streaming (launch-time DPR).
    pub dpr_j: f64,
    /// Live-migration restream + bank copies.
    pub migration_j: f64,
    /// Wake handshakes of gated domains.
    pub wake_j: f64,
    /// Attributed joules per task id (active + DPR + migration share).
    pub per_task: BTreeMap<String, f64>,
    /// Attributed joules per tenant.
    pub per_tenant: [f64; 4],
    /// Cycles integrated over.
    pub horizon_cycles: u64,
    /// Mean power over the horizon, watts.
    pub mean_watts: f64,
    /// Highest windowed-average power observed, watts.
    pub peak_window_watts: f64,
    /// Launch options the power-cap governor refused.
    pub throttled: u64,
    /// Gated-domain wake events charged.
    pub wakes: u64,
}

impl EnergyReport {
    /// Sum of the per-component counters — the conservation invariant
    /// checks this against `total_j`.
    pub fn component_sum_j(&self) -> f64 {
        self.pe_j
            + self.mem_j
            + self.glb_j
            + self.idle_j
            + self.gated_j
            + self.static_j
            + self.dpr_j
            + self.migration_j
            + self.wake_j
    }

    /// Fold another shard's report into this one (pool aggregation):
    /// joules add, the horizon is the longest shard's, peaks take the
    /// max, and the mean is re-derived from the merged totals.
    pub fn merge(&mut self, other: &EnergyReport, clock_mhz: u32) {
        self.total_j += other.total_j;
        self.pe_j += other.pe_j;
        self.mem_j += other.mem_j;
        self.glb_j += other.glb_j;
        self.idle_j += other.idle_j;
        self.gated_j += other.gated_j;
        self.static_j += other.static_j;
        self.dpr_j += other.dpr_j;
        self.migration_j += other.migration_j;
        self.wake_j += other.wake_j;
        for (task, j) in &other.per_task {
            *self.per_task.entry(task.clone()).or_insert(0.0) += j;
        }
        for (mine, theirs) in self.per_tenant.iter_mut().zip(other.per_tenant.iter()) {
            *mine += theirs;
        }
        self.horizon_cycles = self.horizon_cycles.max(other.horizon_cycles);
        self.peak_window_watts = self.peak_window_watts.max(other.peak_window_watts);
        self.throttled += other.throttled;
        self.wakes += other.wakes;
        let seconds = self.horizon_cycles as f64 / (clock_mhz as f64 * 1e6);
        self.mean_watts = if seconds > 0.0 { self.total_j / seconds } else { 0.0 };
    }
}

/// One running region's steady-state draw and attribution identity.
#[derive(Clone, Debug)]
struct RegionDraw {
    power: ActivePower,
    task: String,
    tenant: u32,
}

/// Integrates per-component power into joule counters and enforces the
/// power cap (see module docs).
#[derive(Clone, Debug)]
pub struct EnergyAccountant {
    enabled: bool,
    model: EnergyModel,
    /// Cycle the accumulators are integrated up to.
    last: u64,
    /// Total pJ/cycle drawn at `last` (governor's projection base).
    last_rate_pj: f64,
    regions: BTreeMap<RegionId, RegionDraw>,
    // cumulative pJ per component
    pe: f64,
    mem: f64,
    glb: f64,
    idle: f64,
    gated: f64,
    statik: f64,
    dpr: f64,
    migration: f64,
    wake: f64,
    total: f64,
    per_task: BTreeMap<String, f64>,
    per_tenant: [f64; 4],
    /// (cycle, cumulative total pJ) checkpoints for the windowed average.
    window: VecDeque<(u64, f64)>,
    window_cycles: u64,
    peak_window_pj: f64,
    cap_pj: Option<f64>,
    throttled: u64,
    wakes: u64,
}

impl EnergyAccountant {
    /// Accountant over `model`; a disabled accountant is a no-op on
    /// every path (zero cost, zero state, `report()` returns `None`).
    pub fn new(model: EnergyModel, enabled: bool) -> EnergyAccountant {
        let window_cycles = model.config().power_window_cycles.max(1);
        let cap_pj = if enabled { model.cap_pj_per_cycle() } else { None };
        EnergyAccountant {
            enabled,
            model,
            last: 0,
            last_rate_pj: 0.0,
            regions: BTreeMap::new(),
            pe: 0.0,
            mem: 0.0,
            glb: 0.0,
            idle: 0.0,
            gated: 0.0,
            statik: 0.0,
            dpr: 0.0,
            migration: 0.0,
            wake: 0.0,
            total: 0.0,
            per_task: BTreeMap::new(),
            per_tenant: [0.0; 4],
            window: VecDeque::new(),
            window_cycles,
            peak_window_pj: 0.0,
            cap_pj,
            throttled: 0,
            wakes: 0,
        }
    }

    /// Whether accounting is active.
    pub fn enabled(&self) -> bool {
        self.enabled
    }

    /// The resolved model (policy scoring reads the same numbers the
    /// accountant charges).
    pub fn model(&self) -> &EnergyModel {
        &self.model
    }

    /// Integrate the piecewise-constant power state from the last
    /// advance up to `now`.  `idle_free` / `gated_free` are the fabric's
    /// current unallocated slice counts per class `(glb, array)`.
    ///
    /// A `now` earlier than the last advance resets the integration
    /// baseline instead of integrating backwards — the serving leader
    /// restarts its virtual clock per batch while the fabric is drained,
    /// so cumulative joules stay correct across batches.
    pub fn advance(&mut self, now: u64, idle_free: (u32, u32), gated_free: (u32, u32)) {
        if !self.enabled {
            return;
        }
        if now < self.last {
            self.last = now;
            self.window.clear();
            self.window.push_back((now, self.total));
            return;
        }
        let dt = (now - self.last) as f64;
        // Rates are recomputed from the *current* state on every advance
        // — including zero-dt ones — so the governor's projection base
        // (`last_rate_pj`) tracks completions that freed slices back to
        // idle within the same cycle, instead of going stale until the
        // next time-advancing event.
        let mut pe = 0.0;
        let mut mem = 0.0;
        let mut glb = 0.0;
        let mut held_idle = 0.0;
        for draw in self.regions.values() {
            pe += draw.power.pe_pj;
            mem += draw.power.mem_pj;
            glb += draw.power.glb_pj;
            held_idle += draw.power.held_idle_pj;
        }
        let idle_rate = held_idle
            + idle_free.0 as f64 * self.model.glb_slice_idle_pj()
            + idle_free.1 as f64 * self.model.array_slice_idle_pj();
        let gated_rate = gated_free.0 as f64 * self.model.glb_slice_gated_pj()
            + gated_free.1 as f64 * self.model.array_slice_gated_pj();
        let static_rate = self.model.fabric_overhead_pj(!self.regions.is_empty());
        let rate = pe + mem + glb + idle_rate + gated_rate + static_rate;
        if dt > 0.0 {
            self.pe += pe * dt;
            self.mem += mem * dt;
            self.glb += glb * dt;
            self.idle += idle_rate * dt;
            self.gated += gated_rate * dt;
            self.statik += static_rate * dt;
            self.total += rate * dt;
            // active + over-held energy is attributed to the task/tenant
            for draw in self.regions.values() {
                let pj = draw.power.total() * dt;
                *self.per_task.entry(draw.task.clone()).or_insert(0.0) += pj;
                self.per_tenant[draw.tenant as usize % 4] += pj;
            }
            self.last = now;
        }
        self.last_rate_pj = rate;
        self.push_window_point(now);
    }

    fn push_window_point(&mut self, now: u64) {
        // same cycle: keep only the latest cumulative value
        if matches!(self.window.back(), Some(&(at, _)) if at == now) {
            self.window.pop_back();
        }
        self.window.push_back((now, self.total));
        let horizon = now.saturating_sub(self.window_cycles);
        // keep exactly one checkpoint at or before the window boundary —
        // cumulative energy is piecewise-linear between checkpoints, so
        // interpolating across that entry is exact
        while self.window.len() > 2 && self.window[1].0 <= horizon {
            self.window.pop_front();
        }
        let w = self.windowed_pj_per_cycle(now);
        if w > self.peak_window_pj {
            self.peak_window_pj = w;
        }
    }

    /// Average pJ/cycle over the trailing window ending at `now`.
    ///
    /// The denominator is always the full window length: energy before
    /// the accounting baseline counts as zero (the fabric was off), so
    /// the average ramps up from a cold start instead of dividing a
    /// one-shot launch charge by a micro-span and reporting a phantom
    /// spike.  With the governor holding the instantaneous rate at or
    /// below the cap, this average therefore can never exceed the cap
    /// by more than the one-shot charges amortized over a whole window.
    fn windowed_pj_per_cycle(&self, now: u64) -> f64 {
        let start = now.saturating_sub(self.window_cycles);
        let Some(&(c0, e0)) = self.window.front() else { return 0.0 };
        // cumulative energy at the window start: the baseline value if
        // the run is younger than one window, else interpolated on the
        // piecewise-linear segment bracketing `start` (exact — energy
        // is linear between event checkpoints)
        let e_start = if c0 >= start {
            e0
        } else {
            let mut prev = (c0, e0);
            let mut at_start = e0;
            for &(c, e) in self.window.iter() {
                if c >= start {
                    let span = (c - prev.0) as f64;
                    at_start = if span > 0.0 {
                        prev.1 + (e - prev.1) * ((start - prev.0) as f64 / span)
                    } else {
                        e
                    };
                    break;
                }
                prev = (c, e);
            }
            at_start
        };
        (self.total - e_start).max(0.0) / self.window_cycles as f64
    }

    /// Windowed average power at `now`, watts.
    pub fn windowed_watts(&self, now: u64) -> f64 {
        if !self.enabled {
            return 0.0;
        }
        self.model.pj_per_cycle_to_watts(self.windowed_pj_per_cycle(now))
    }

    /// Windowed average power at the last integration point, watts.
    pub fn current_windowed_watts(&self) -> f64 {
        self.windowed_watts(self.last)
    }

    /// Total accumulated energy, joules.
    pub fn total_joules(&self) -> f64 {
        self.total * PJ_TO_J
    }

    /// Power-cap governor: may a launch drawing `add` more pJ/cycle
    /// start now?  Uncapped (or disabled) accountants always admit; a
    /// drained fabric admits one task regardless, guaranteeing progress.
    pub fn admits(&mut self, add: &ActivePower) -> bool {
        let Some(cap) = self.cap_pj else { return true };
        if self.regions.is_empty() {
            return true;
        }
        if self.last_rate_pj + add.total() <= cap {
            true
        } else {
            self.throttled += 1;
            false
        }
    }

    /// Launch options refused by the governor so far.
    pub fn throttled(&self) -> u64 {
        self.throttled
    }

    /// Register a launched region's steady draw and charge its one-shot
    /// launch costs (configuration stream + domain wake).  `duty_scale`
    /// scales the assumed GLB stream duty for the region's steady draw
    /// (the NoC contention path, [`crate::noc`]); pass 1.0 when corridor
    /// tracking is off for a bit-exact legacy draw.
    #[allow(clippy::too_many_arguments)]
    pub fn on_launch(
        &mut self,
        region: RegionId,
        demand: &SliceDemand,
        held: &SliceDemand,
        task: &str,
        tenant: u32,
        dpr_words: u64,
        cache_hit: bool,
        woken: (u32, u32),
        duty_scale: f64,
    ) {
        if !self.enabled {
            return;
        }
        let power = self.model.region_power_scaled(demand, held, duty_scale);
        let dpr_pj = self.model.dpr_stream_pj(dpr_words, cache_hit);
        let wake_pj = self.model.wake_pj(woken.0, woken.1);
        self.dpr += dpr_pj;
        self.wake += wake_pj;
        self.total += dpr_pj + wake_pj;
        if woken.0 + woken.1 > 0 {
            self.wakes += 1;
        }
        *self.per_task.entry(task.to_string()).or_insert(0.0) += dpr_pj + wake_pj;
        self.per_tenant[tenant as usize % 4] += dpr_pj + wake_pj;
        self.regions.insert(
            region,
            RegionDraw { power, task: task.to_string(), tenant },
        );
        // the steady-state draw changed; refresh the governor's base so
        // back-to-back admits within one scheduling step stack up
        self.last_rate_pj += power.total();
    }

    /// Drop a completed region's draw.
    pub fn on_complete(&mut self, region: RegionId) {
        if !self.enabled {
            return;
        }
        if let Some(draw) = self.regions.remove(&region) {
            self.last_rate_pj = (self.last_rate_pj - draw.power.total()).max(0.0);
        }
    }

    /// Charge one migration step's energy to a task/tenant: the
    /// restream/copy bill (`pj`, migration component) plus the wake
    /// bill when the relocation target was power-gated (`wake_pj`,
    /// wake component).
    pub fn on_migration(&mut self, pj: f64, wake_pj: f64, task: &str, tenant: u32) {
        if !self.enabled {
            return;
        }
        self.migration += pj;
        self.wake += wake_pj;
        self.total += pj + wake_pj;
        *self.per_task.entry(task.to_string()).or_insert(0.0) += pj + wake_pj;
        self.per_tenant[tenant as usize % 4] += pj + wake_pj;
    }

    /// Final report (`None` when accounting is disabled).
    pub fn report(&self) -> Option<EnergyReport> {
        if !self.enabled {
            return None;
        }
        let seconds = self.last as f64 / (self.model.clock_mhz() as f64 * 1e6);
        Some(EnergyReport {
            total_j: self.total * PJ_TO_J,
            pe_j: self.pe * PJ_TO_J,
            mem_j: self.mem * PJ_TO_J,
            glb_j: self.glb * PJ_TO_J,
            idle_j: self.idle * PJ_TO_J,
            gated_j: self.gated * PJ_TO_J,
            static_j: self.statik * PJ_TO_J,
            dpr_j: self.dpr * PJ_TO_J,
            migration_j: self.migration * PJ_TO_J,
            wake_j: self.wake * PJ_TO_J,
            per_task: self
                .per_task
                .iter()
                .map(|(k, v)| (k.clone(), v * PJ_TO_J))
                .collect(),
            per_tenant: [
                self.per_tenant[0] * PJ_TO_J,
                self.per_tenant[1] * PJ_TO_J,
                self.per_tenant[2] * PJ_TO_J,
                self.per_tenant[3] * PJ_TO_J,
            ],
            horizon_cycles: self.last,
            mean_watts: if seconds > 0.0 { self.total * PJ_TO_J / seconds } else { 0.0 },
            peak_window_watts: self.model.pj_per_cycle_to_watts(self.peak_window_pj),
            throttled: self.throttled,
            wakes: self.wakes,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ArchConfig, EnergyConfig};

    fn meter(enabled: bool) -> EnergyAccountant {
        let model = EnergyModel::new(&ArchConfig::default(), &EnergyConfig::default());
        EnergyAccountant::new(model, enabled)
    }

    #[test]
    fn disabled_meter_is_inert() {
        let mut m = meter(false);
        m.advance(1000, (32, 8), (0, 0));
        m.on_launch(
            RegionId(0),
            &SliceDemand::new(4, 2),
            &SliceDemand::new(4, 2),
            "t",
            0,
            1000,
            true,
            (0, 0),
            1.0,
        );
        assert_eq!(m.total_joules(), 0.0);
        assert!(m.report().is_none());
        assert!(m.admits(&ActivePower::default()));
    }

    #[test]
    fn integrates_idle_floor_and_conserves() {
        let mut m = meter(true);
        m.advance(0, (32, 8), (0, 0));
        m.advance(1_000_000, (32, 8), (0, 0));
        let r = m.report().unwrap();
        assert!(r.total_j > 0.0);
        assert!((r.component_sum_j() - r.total_j).abs() <= 1e-9 * r.total_j.max(1.0));
        assert_eq!(r.pe_j, 0.0, "no region ran");
        assert!(r.idle_j > 0.0);
        assert!(r.static_j > 0.0);
        assert!(r.mean_watts > 0.0);
    }

    #[test]
    fn launch_complete_cycle_attributes_energy() {
        let mut m = meter(true);
        let d = SliceDemand::new(4, 2);
        m.advance(0, (32, 8), (0, 0));
        m.on_launch(RegionId(7), &d, &d, "harris.corner", 3, 6656, true, (4, 2), 1.0);
        m.advance(100_000, (28, 6), (0, 0));
        m.on_complete(RegionId(7));
        m.advance(200_000, (32, 8), (0, 0));
        let r = m.report().unwrap();
        assert!(r.pe_j > 0.0 && r.mem_j > 0.0 && r.glb_j > 0.0);
        assert!(r.dpr_j > 0.0);
        assert!(r.wake_j > 0.0);
        assert_eq!(r.wakes, 1);
        assert!(r.per_task["harris.corner"] > 0.0);
        assert!(r.per_tenant[3] > 0.0);
        assert!((r.component_sum_j() - r.total_j).abs() <= 1e-9 * r.total_j);
        // attribution never exceeds the total
        assert!(r.per_tenant.iter().sum::<f64>() <= r.total_j);
    }

    #[test]
    fn windowed_power_tracks_load_changes() {
        let cfg = EnergyConfig { power_window_cycles: 10_000, ..EnergyConfig::default() };
        let model = EnergyModel::new(&ArchConfig::default(), &cfg);
        let mut m = EnergyAccountant::new(model, true);
        let d = SliceDemand::new(32, 8);
        m.advance(0, (32, 8), (0, 0));
        m.on_launch(RegionId(0), &d, &d, "t", 0, 0, true, (0, 0), 1.0);
        m.advance(50_000, (0, 0), (0, 0));
        let busy_w = m.windowed_watts(50_000);
        m.on_complete(RegionId(0));
        m.advance(200_000, (32, 8), (0, 0));
        let idle_w = m.windowed_watts(200_000);
        assert!(busy_w > 4.0 * idle_w, "busy {busy_w} vs idle {idle_w}");
        let r = m.report().unwrap();
        assert!(r.peak_window_watts >= busy_w - 1e-9);
    }

    #[test]
    fn governor_throttles_above_cap_but_never_deadlocks() {
        let cfg = EnergyConfig { power_cap_watts: 1.0, ..EnergyConfig::default() };
        let model = EnergyModel::new(&ArchConfig::default(), &cfg);
        let big = model.region_power(&SliceDemand::new(32, 8), &SliceDemand::new(32, 8));
        let mut m = EnergyAccountant::new(model, true);
        // drained fabric: always admits (progress guarantee)
        assert!(m.admits(&big));
        m.on_launch(
            RegionId(0),
            &SliceDemand::new(32, 8),
            &SliceDemand::new(32, 8),
            "t",
            0,
            0,
            true,
            (0, 0),
            1.0,
        );
        // now over cap: further launches are refused and counted
        assert!(!m.admits(&big));
        assert_eq!(m.throttled(), 1);
        m.on_complete(RegionId(0));
        assert!(m.admits(&big), "drained again");
    }

    #[test]
    fn clock_restart_resets_baseline_without_negative_time() {
        let mut m = meter(true);
        m.advance(0, (32, 8), (0, 0));
        m.advance(100_000, (32, 8), (0, 0));
        let before = m.total_joules();
        // leader batch restart: clock goes back to 0
        m.advance(0, (32, 8), (0, 0));
        assert_eq!(m.total_joules(), before, "no backwards integration");
        m.advance(50_000, (32, 8), (0, 0));
        assert!(m.total_joules() > before);
    }

    #[test]
    fn merge_sums_and_rederives_mean() {
        let mut m1 = meter(true);
        m1.advance(0, (32, 8), (0, 0));
        m1.advance(100_000, (32, 8), (0, 0));
        let mut r1 = m1.report().unwrap();
        let r2 = r1.clone();
        let single_mean = r1.mean_watts;
        r1.merge(&r2, 500);
        assert!((r1.total_j - 2.0 * r2.total_j).abs() < 1e-12);
        assert_eq!(r1.horizon_cycles, r2.horizon_cycles);
        assert!((r1.mean_watts - 2.0 * single_mean).abs() < 1e-9);
    }
}
