//! Artifact manifest: the contract between `aot.py` and the runtime.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::error::{Error, Result};
use crate::util::json::Json;

/// One runtime input tensor: shape, dtype, deterministic-fill parameters.
///
/// Weights are runtime arguments (never baked constants — the HLO text
/// printer elides large literals), so every input carries the `[lo, hi]`
/// range and `salt` of the low-discrepancy fill both sides regenerate.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    /// Dimensions.
    pub shape: Vec<usize>,
    /// Element type name (`"f32"`).
    pub dtype: String,
    /// Fill range `[lo, hi]`.
    pub range: (f64, f64),
    /// Fill stream salt (argument index).
    pub salt: u64,
    /// `"activation"` or `"weight"` (documentation only).
    pub role: String,
}

impl TensorSpec {
    /// Element count.
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Golden checksum captured by `aot.py` on the deterministic inputs.
#[derive(Clone, Debug, PartialEq)]
pub struct Golden {
    /// Sum of all output elements (f64 accumulation).
    pub sum: f64,
    /// Sum of absolute values.
    pub abs_sum: f64,
    /// First eight output elements.
    pub head: Vec<f64>,
}

/// One AOT artifact.
#[derive(Clone, Debug, PartialEq)]
pub struct ArtifactSpec {
    /// Artifact name (e.g. `resnet_conv2_a`).
    pub name: String,
    /// HLO text file name within the artifacts dir.
    pub file: String,
    /// Owning Table 1 task id.
    pub task: String,
    /// Variant letter.
    pub variant: String,
    /// Input tensors, in argument order.
    pub inputs: Vec<TensorSpec>,
    /// Output tensor (shape + dtype; range/salt unused).
    pub output_shape: Vec<usize>,
    /// Golden checksum.
    pub golden: Golden,
    /// HLO text size in bytes (consistency check).
    pub hlo_bytes: u64,
}

impl ArtifactSpec {
    /// Output element count.
    pub fn output_elements(&self) -> usize {
        self.output_shape.iter().product()
    }
}

/// Parsed manifest.json.
#[derive(Clone, Debug)]
pub struct Manifest {
    /// Directory the manifest was loaded from.
    pub dir: PathBuf,
    /// Manifest schema version.
    pub version: u64,
    /// Artifact size class (`small` / `tiny`).
    pub size: String,
    artifacts: BTreeMap<String, ArtifactSpec>,
}

/// Manifest schema version this runtime understands.
pub const SUPPORTED_VERSION: u64 = 3;

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| Error::io(path.display().to_string(), e))?;
        Manifest::parse(dir, &text)
    }

    /// Parse manifest text (factored out for tests).
    pub fn parse(dir: &Path, text: &str) -> Result<Manifest> {
        let root = Json::parse(text)?;
        let version = root.req_u64("version")?;
        if version != SUPPORTED_VERSION {
            return Err(Error::Artifact(format!(
                "manifest version {version} unsupported (runtime expects {SUPPORTED_VERSION}; \
                 re-run `make artifacts`)"
            )));
        }
        let size = root.req_str("size")?.to_string();
        let mut artifacts = BTreeMap::new();
        for entry in root.req("artifacts")?.items() {
            let spec = parse_artifact(entry)?;
            if artifacts.insert(spec.name.clone(), spec).is_some() {
                return Err(Error::Artifact("duplicate artifact name in manifest".into()));
            }
        }
        if artifacts.is_empty() {
            return Err(Error::Artifact("manifest lists no artifacts".into()));
        }
        Ok(Manifest { dir: dir.to_path_buf(), version, size, artifacts })
    }

    /// Artifact lookup by name.
    pub fn get(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .ok_or_else(|| Error::Artifact(format!("unknown artifact '{name}'")))
    }

    /// All artifacts, name-ordered.
    pub fn iter(&self) -> impl Iterator<Item = &ArtifactSpec> {
        self.artifacts.values()
    }

    /// Artifact count.
    pub fn len(&self) -> usize {
        self.artifacts.len()
    }

    /// Whether the manifest is empty (never true after `load`).
    pub fn is_empty(&self) -> bool {
        self.artifacts.is_empty()
    }

    /// Absolute path of an artifact's HLO file.
    pub fn hlo_path(&self, spec: &ArtifactSpec) -> PathBuf {
        self.dir.join(&spec.file)
    }

    /// Built-in synthetic manifest for the stub runtime backend: one
    /// small artifact per task variant of the pipeline-extended Table 1
    /// library (the 19 paper names plus the two demosaic stages) and a
    /// `matmul_128` smoke artifact.  Golden
    /// checksums are computed with [`crate::runtime::stub_output`] — the same function
    /// the stub executor runs — so stub-mode golden verification passes
    /// exactly and still catches arity/shape/ordering bugs.  Selected by
    /// `artifacts_dir = "synthetic"` (see [`crate::runtime::SYNTHETIC_DIR`]).
    pub fn synthetic() -> Manifest {
        use super::inputs::{checksum_of, golden_input, stub_output};

        let mut artifacts = BTreeMap::new();
        let mut add = |name: &str, task: &str, variant: &str| {
            let inputs = vec![
                TensorSpec {
                    shape: vec![16, 16],
                    dtype: "f32".into(),
                    range: (-1.0, 1.0),
                    salt: 0,
                    role: "activation".into(),
                },
                TensorSpec {
                    shape: vec![16, 16],
                    dtype: "f32".into(),
                    range: (-0.5, 0.5),
                    salt: 1,
                    role: "weight".into(),
                },
            ];
            let output_shape = vec![16usize, 16];
            let args: Vec<Vec<f32>> = inputs
                .iter()
                .map(|t| golden_input(t.elements(), t.range.0, t.range.1, t.salt))
                .collect();
            let values = stub_output(name, &args, output_shape.iter().product());
            let cs = checksum_of(&values);
            artifacts.insert(
                name.to_string(),
                ArtifactSpec {
                    name: name.to_string(),
                    file: format!("{name}.hlo.txt"),
                    task: task.to_string(),
                    variant: variant.to_string(),
                    inputs,
                    output_shape,
                    golden: Golden { sum: cs.sum, abs_sum: cs.abs_sum, head: cs.head },
                    hlo_bytes: 0,
                },
            );
        };
        for t in crate::tasks::TaskLibrary::table1_pipeline().iter() {
            for v in &t.variants {
                if let Some(name) = &v.artifact {
                    add(name, &t.id.0, &v.ver.0.to_string());
                }
            }
        }
        add("matmul_128", "demo.matmul", "a");
        Manifest {
            dir: PathBuf::from(super::SYNTHETIC_DIR),
            version: SUPPORTED_VERSION,
            size: "synthetic".into(),
            artifacts,
        }
    }

    /// Whether this manifest is the built-in synthetic one (no files on
    /// disk back it, so [`Manifest::verify_files`] does not apply).
    pub fn is_synthetic(&self) -> bool {
        self.dir == Path::new(super::SYNTHETIC_DIR)
    }

    /// Verify files exist and sizes match the manifest.
    pub fn verify_files(&self) -> Result<()> {
        for spec in self.iter() {
            let path = self.hlo_path(spec);
            let meta = std::fs::metadata(&path)
                .map_err(|e| Error::io(path.display().to_string(), e))?;
            if meta.len() != spec.hlo_bytes {
                return Err(Error::Artifact(format!(
                    "{}: size {} != manifest {}",
                    spec.name,
                    meta.len(),
                    spec.hlo_bytes
                )));
            }
        }
        Ok(())
    }
}

fn parse_input(v: &Json) -> Result<TensorSpec> {
    let shape = v
        .req("shape")?
        .items()
        .iter()
        .map(|d| {
            d.as_u64()
                .map(|u| u as usize)
                .ok_or_else(|| Error::parse("input.shape", "bad shape dim"))
        })
        .collect::<Result<Vec<_>>>()?;
    let range = v.req("range")?.items();
    if range.len() != 2 {
        return Err(Error::Artifact("input.range must be [lo, hi]".into()));
    }
    Ok(TensorSpec {
        shape,
        dtype: v.req_str("dtype")?.to_string(),
        range: (
            range[0].as_f64().ok_or_else(|| Error::Artifact("bad range lo".into()))?,
            range[1].as_f64().ok_or_else(|| Error::Artifact("bad range hi".into()))?,
        ),
        salt: v.req_u64("salt")?,
        role: v
            .get("role")
            .and_then(|r| r.as_str())
            .unwrap_or("activation")
            .to_string(),
    })
}

fn parse_artifact(entry: &Json) -> Result<ArtifactSpec> {
    let inputs = entry
        .req("inputs")?
        .items()
        .iter()
        .map(parse_input)
        .collect::<Result<Vec<_>>>()?;
    if inputs.is_empty() {
        return Err(Error::Artifact("artifact with no inputs".into()));
    }
    let output_shape = entry
        .req("output")?
        .req("shape")?
        .items()
        .iter()
        .map(|d| {
            d.as_u64()
                .map(|u| u as usize)
                .ok_or_else(|| Error::parse("output.shape", "bad shape dim"))
        })
        .collect::<Result<Vec<_>>>()?;
    let golden_json = entry.req("golden")?;
    Ok(ArtifactSpec {
        name: entry.req_str("name")?.to_string(),
        file: entry.req_str("file")?.to_string(),
        task: entry.req_str("task")?.to_string(),
        variant: entry.req_str("variant")?.to_string(),
        inputs,
        output_shape,
        golden: Golden {
            sum: golden_json.req_f64("sum")?,
            abs_sum: golden_json.req_f64("abs_sum")?,
            head: golden_json
                .req("head")?
                .items()
                .iter()
                .map(|h| h.as_f64().ok_or_else(|| Error::Artifact("bad golden head".into())))
                .collect::<Result<Vec<_>>>()?,
        },
        hlo_bytes: entry.req_u64("hlo_bytes")?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 3, "size": "tiny",
      "artifacts": [
        {"name": "demo_a", "file": "demo_a.hlo.txt", "task": "demo.t", "variant": "a",
         "tags": [],
         "inputs": [
            {"shape": [2, 3], "dtype": "f32", "range": [0.0, 1.0], "salt": 0, "role": "activation"},
            {"shape": [3, 4], "dtype": "f32", "range": [-0.5, 0.5], "salt": 1, "role": "weight"}
         ],
         "output": {"shape": [2, 4], "dtype": "f32"},
         "golden": {"sum": 1.5, "abs_sum": 2.0, "head": [0.1, 0.2]},
         "hlo_bytes": 123}
      ]
    }"#;

    #[test]
    fn parses_sample_manifest() {
        let m = Manifest::parse(Path::new("/tmp/a"), SAMPLE).unwrap();
        assert_eq!(m.version, 3);
        assert_eq!(m.len(), 1);
        let a = m.get("demo_a").unwrap();
        assert_eq!(a.inputs.len(), 2);
        assert_eq!(a.inputs[0].shape, vec![2, 3]);
        assert_eq!(a.inputs[1].role, "weight");
        assert_eq!(a.inputs[1].salt, 1);
        assert_eq!(a.output_elements(), 8);
        assert!(m.get("nope").is_err());
        assert_eq!(m.hlo_path(a), Path::new("/tmp/a/demo_a.hlo.txt"));
    }

    #[test]
    fn rejects_wrong_version() {
        let old = SAMPLE.replace("\"version\": 3", "\"version\": 2");
        let err = Manifest::parse(Path::new("."), &old).unwrap_err().to_string();
        assert!(err.contains("version"), "{err}");
    }

    #[test]
    fn rejects_bad_manifests() {
        assert!(Manifest::parse(Path::new("."), "{}").is_err());
        assert!(
            Manifest::parse(Path::new("."), r#"{"version":3,"size":"s","artifacts":[]}"#).is_err()
        );
        let no_inputs = SAMPLE.replace(
            r#""inputs": [
            {"shape": [2, 3], "dtype": "f32", "range": [0.0, 1.0], "salt": 0, "role": "activation"},
            {"shape": [3, 4], "dtype": "f32", "range": [-0.5, 0.5], "salt": 1, "role": "weight"}
         ]"#,
            r#""inputs": []"#,
        );
        assert!(Manifest::parse(Path::new("."), &no_inputs).is_err());
    }

    #[test]
    fn synthetic_manifest_covers_table1_and_self_verifies() {
        let m = Manifest::synthetic();
        assert!(m.is_synthetic());
        assert_eq!(m.version, SUPPORTED_VERSION);
        // 19 Table 1 variants + 2 demosaic stages + matmul_128
        assert_eq!(m.len(), 22);
        for t in crate::tasks::TaskLibrary::table1_pipeline().iter() {
            for v in &t.variants {
                let name = v.artifact.as_ref().unwrap();
                let spec = m.get(name).unwrap();
                assert_eq!(spec.task, t.id.0);
                assert!(spec.output_elements() > 0);
                assert!(spec.golden.abs_sum > 0.0, "{name}: degenerate golden");
            }
        }
    }

    #[test]
    fn real_manifest_loads_if_built() {
        // integration sanity: when `make artifacts` has run, the real
        // manifest must parse and cover every Table 1 artifact name used
        // by the task library.
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            return; // artifacts not built in this environment
        }
        let m = Manifest::load(&dir).unwrap();
        assert!(m.len() >= 19, "{}", m.len());
        m.verify_files().unwrap();
        for t in crate::tasks::TaskLibrary::table1().iter() {
            for v in &t.variants {
                let name = v.artifact.as_ref().unwrap();
                assert!(m.get(name).is_ok(), "missing artifact {name}");
            }
        }
    }
}
