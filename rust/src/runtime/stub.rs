//! Deterministic in-process stub executor — the default runtime backend.
//!
//! Serves the exact API of the PJRT client in `client.rs` (selected with
//! `--features xla`) so the coordinator, benches, examples and tests
//! build and run fully offline: "compilation" records a deterministic
//! pseudo-cost, "execution" synthesizes output tensors from the artifact
//! name and input digests via [`stub_output`].
//!
//! Two manifest sources work in stub mode:
//!
//! * the built-in synthetic manifest ([`Manifest::synthetic`]), selected
//!   by the [`super::SYNTHETIC_DIR`] sentinel (`artifacts_dir =
//!   "synthetic"`): golden checksums were computed with the same stub
//!   function, so [`RuntimeClient::verify_golden`] passes exactly;
//! * a real `manifest.json` produced by `make artifacts`: loading works,
//!   but golden verification will fail because the stub does not run the
//!   HLO — use `--features xla` for real numerics.

use std::collections::BTreeMap;
use std::path::Path;
use std::time::Instant;

use crate::error::{Error, Result};

use super::artifact::Manifest;
use super::exec::ExecOutput;
use super::inputs::{golden_input, stub_output};

/// Stub runtime with the PJRT client's compile-once caching shape.
pub struct RuntimeClient {
    manifest: Manifest,
    /// pseudo compile wall-times per artifact, microseconds.
    compile_us: BTreeMap<String, f64>,
    /// memoized golden argument sets (mirrors the PJRT client).
    golden_cache: BTreeMap<String, Vec<Vec<f32>>>,
}

impl RuntimeClient {
    /// Create a stub client over a loaded manifest.
    pub fn new(manifest: Manifest) -> Result<RuntimeClient> {
        Ok(RuntimeClient {
            manifest,
            compile_us: BTreeMap::new(),
            golden_cache: BTreeMap::new(),
        })
    }

    /// Convenience: load the manifest from a directory and connect.  The
    /// sentinel directory [`super::SYNTHETIC_DIR`] selects the built-in
    /// synthetic manifest; any other path must contain `manifest.json`.
    pub fn from_dir(dir: impl AsRef<Path>) -> Result<RuntimeClient> {
        let dir = dir.as_ref();
        if dir == Path::new(super::SYNTHETIC_DIR) {
            return RuntimeClient::new(Manifest::synthetic());
        }
        RuntimeClient::new(Manifest::load(dir)?)
    }

    /// A client over the built-in synthetic manifest.
    pub fn synthetic() -> RuntimeClient {
        RuntimeClient::new(Manifest::synthetic()).expect("synthetic manifest is infallible")
    }

    /// Backend name (diagnostics).
    pub fn platform(&self) -> String {
        "stub-cpu".to_string()
    }

    /// The manifest in use.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Number of "compiled" executables resident.
    pub fn compiled_count(&self) -> usize {
        self.compile_us.len()
    }

    /// Compile-time (µs) of an already-compiled artifact.
    pub fn compile_us(&self, name: &str) -> Option<f64> {
        self.compile_us.get(name).copied()
    }

    /// Ensure an artifact is "compiled"; returns its pseudo compile time
    /// in µs (0 if it was already cached).  The cost is deterministic
    /// and scales with tensor volume so warmup accounting stays
    /// meaningful.
    pub fn ensure_compiled(&mut self, name: &str) -> Result<f64> {
        if self.compile_us.contains_key(name) {
            return Ok(0.0);
        }
        let spec = self.manifest.get(name)?;
        let volume: usize =
            spec.inputs.iter().map(|t| t.elements()).sum::<usize>() + spec.output_elements();
        let us = 50.0 + volume as f64 * 0.01;
        self.compile_us.insert(name.to_string(), us);
        Ok(us)
    }

    /// Execute an artifact on caller-provided argument tensors (one
    /// flattened f32 buffer per manifest input, in order).
    pub fn execute(&mut self, name: &str, args: &[Vec<f32>]) -> Result<ExecOutput> {
        self.ensure_compiled(name)?;
        let spec = self.manifest.get(name)?.clone();
        if args.len() != spec.inputs.len() {
            return Err(Error::Runtime(format!(
                "{name}: got {} args, artifact expects {}",
                args.len(),
                spec.inputs.len()
            )));
        }
        for (arg, input) in args.iter().zip(&spec.inputs) {
            if arg.len() != input.elements() {
                return Err(Error::Runtime(format!(
                    "{name}: arg has {} elements, artifact expects {}",
                    arg.len(),
                    input.elements()
                )));
            }
        }
        let t0 = Instant::now();
        let values = stub_output(name, args, spec.output_elements());
        let exec_us = (t0.elapsed().as_secs_f64() * 1e6).max(0.01);
        Ok(ExecOutput { values, shape: spec.output_shape.clone(), exec_us })
    }

    /// Synthesize the deterministic argument set for an artifact.
    pub fn golden_args(&self, name: &str) -> Result<Vec<Vec<f32>>> {
        let spec = self.manifest.get(name)?;
        Ok(spec
            .inputs
            .iter()
            .map(|t| golden_input(t.elements(), t.range.0, t.range.1, t.salt))
            .collect())
    }

    /// Execute on the deterministic golden inputs (memoized).
    pub fn execute_golden(&mut self, name: &str) -> Result<ExecOutput> {
        if !self.golden_cache.contains_key(name) {
            let args = self.golden_args(name)?;
            self.golden_cache.insert(name.to_string(), args);
        }
        let args = self.golden_cache.get(name).expect("just inserted").clone();
        self.execute(name, &args)
    }

    /// Execute on golden input and verify against the manifest checksum.
    /// Returns the output on success.
    pub fn verify_golden(&mut self, name: &str) -> Result<ExecOutput> {
        let out = self.execute_golden(name)?;
        let spec = self.manifest.get(name)?;
        let cs = out.checksum();
        if !cs.close_to(spec.golden.sum, spec.golden.abs_sum, &spec.golden.head, 1e-3) {
            return Err(Error::Runtime(format!(
                "{name}: golden mismatch — got sum={:.6} abs={:.6}, manifest sum={:.6} abs={:.6}",
                cs.sum, cs.abs_sum, spec.golden.sum, spec.golden.abs_sum
            )));
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_artifacts_all_golden_verify() {
        let mut rt = RuntimeClient::synthetic();
        assert_eq!(rt.platform(), "stub-cpu");
        let names: Vec<String> = rt.manifest().iter().map(|a| a.name.clone()).collect();
        assert_eq!(names.len(), 22);
        for name in &names {
            let out = rt.verify_golden(name).unwrap_or_else(|e| panic!("{name}: {e}"));
            assert_eq!(out.shape, vec![16, 16]);
            assert!(out.exec_us > 0.0);
            assert!(out.values.iter().all(|v| v.is_finite()));
        }
        assert_eq!(rt.compiled_count(), names.len());
        assert!(rt.compile_us("harris_a").unwrap() > 0.0);
    }

    #[test]
    fn executions_are_reproducible() {
        let mut rt = RuntimeClient::synthetic();
        let a = rt.execute_golden("camera_pipeline_a").unwrap();
        let b = rt.execute_golden("camera_pipeline_a").unwrap();
        assert_eq!(a.values, b.values);
        // and distinct across artifacts
        let c = rt.execute_golden("camera_pipeline_b").unwrap();
        assert_ne!(a.values, c.values);
    }

    #[test]
    fn input_arity_and_shape_checked() {
        let mut rt = RuntimeClient::synthetic();
        assert!(rt.execute("matmul_128", &[vec![1.0f32; 3]]).is_err());
        assert!(rt
            .execute("matmul_128", &[vec![0.0f32; 3], vec![0.0f32; 3]])
            .is_err());
    }

    #[test]
    fn unknown_artifact_and_missing_dir_error() {
        let mut rt = RuntimeClient::synthetic();
        assert!(rt.execute_golden("no_such_artifact").is_err());
        assert!(RuntimeClient::from_dir("/nonexistent/artifacts").is_err());
    }

    #[test]
    fn sentinel_dir_selects_synthetic() {
        let rt = RuntimeClient::from_dir(crate::runtime::SYNTHETIC_DIR).unwrap();
        assert!(rt.manifest().is_synthetic());
    }
}
