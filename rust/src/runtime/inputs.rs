//! Deterministic input synthesis — bit-identical to `aot.golden_input`.
//!
//! Both sides compute `lo + (hi-lo) * frac((i+1)·φ)` in f64 and cast to
//! f32, so the Rust runtime can regenerate the exact tensors the Python
//! golden checksums were computed on, without shipping tensors around.

/// 1/golden-ratio, the low-discrepancy multiplier (matches aot.py).
pub const PHI: f64 = 0.618_033_988_749_894_9;

/// Distinct fill stream per argument index (matches aot._SALT_STRIDE).
pub const SALT_STRIDE: u64 = 1_000_003;

/// Fill `n` f32 values over `[lo, hi)` deterministically; `salt` selects
/// an independent stream per artifact argument.
pub fn golden_input(n: usize, lo: f64, hi: f64, salt: u64) -> Vec<f32> {
    let offset = (salt * SALT_STRIDE) as f64;
    (0..n)
        .map(|i| {
            let x = (offset + i as f64 + 1.0) * PHI;
            let frac = x - x.trunc();
            (lo + (hi - lo) * frac) as f32
        })
        .collect()
}

/// FNV-1a over a byte string — a stable, dependency-free 64-bit hash
/// used to seed the stub executor's output streams.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// Deterministic stub "execution": synthesize `n` output values from the
/// artifact name and a cheap order-sensitive digest of the input
/// tensors.  Used by the default (no-`xla`) runtime backend and by
/// [`crate::runtime::Manifest::synthetic`], which computes its golden
/// checksums with this same function so stub-mode golden verification is
/// exact.  The digest makes outputs input-dependent (wrong-argument bugs
/// still surface) while staying far cheaper than real compute.
pub fn stub_output(name: &str, args: &[Vec<f32>], n: usize) -> Vec<f32> {
    let mut seed = fnv1a(name.as_bytes());
    for arg in args {
        let sum: f64 = arg.iter().map(|&v| v as f64).sum();
        seed = seed
            .wrapping_mul(0x100_0000_01b3)
            .wrapping_add(arg.len() as u64)
            .wrapping_add((sum * 1024.0) as i64 as u64);
    }
    // keep the salt small so the low-discrepancy stream retains f64
    // fractional precision (huge offsets truncate to constants).
    golden_input(n, -1.0, 1.0, seed % 99_991)
}

/// Output summary mirroring `aot.checksum` (f64 accumulation).
#[derive(Clone, Debug, PartialEq)]
pub struct Checksum {
    /// Σ x
    pub sum: f64,
    /// Σ |x|
    pub abs_sum: f64,
    /// first 8 values
    pub head: Vec<f64>,
}

/// Compute the checksum of an f32 buffer.
pub fn checksum_of(values: &[f32]) -> Checksum {
    let mut sum = 0.0f64;
    let mut abs_sum = 0.0f64;
    for &v in values {
        sum += v as f64;
        abs_sum += (v as f64).abs();
    }
    Checksum {
        sum,
        abs_sum,
        head: values.iter().take(8).map(|&v| v as f64).collect(),
    }
}

impl Checksum {
    /// Tolerant comparison against a manifest golden.
    ///
    /// `rel` bounds the relative error of the aggregate sums; heads are
    /// compared element-wise with a mixed abs/rel tolerance.  CPU PJRT
    /// executes the same HLO the golden was produced with, so mismatches
    /// indicate a loading/layout bug, not float noise — tolerances are
    /// tight.
    pub fn close_to(&self, sum: f64, abs_sum: f64, head: &[f64], rel: f64) -> bool {
        let rel_ok = |a: f64, b: f64| {
            let scale = a.abs().max(b.abs()).max(1e-6);
            (a - b).abs() <= rel * scale
        };
        if !rel_ok(self.sum, sum) || !rel_ok(self.abs_sum, abs_sum) {
            return false;
        }
        if self.head.len() < head.len().min(8) {
            return false;
        }
        head.iter()
            .zip(self.head.iter())
            .all(|(&a, &b)| (a - b).abs() <= rel * a.abs().max(b.abs()).max(1e-4))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv1a_is_stable() {
        // pinned reference values: a regression here would silently
        // change every synthetic golden checksum.
        assert_eq!(fnv1a(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a(b"a"), fnv1a(b"a"));
        assert_ne!(fnv1a(b"harris_a"), fnv1a(b"harris_b"));
    }

    #[test]
    fn stub_output_is_deterministic_and_input_sensitive() {
        let args = vec![vec![1.0f32; 8], vec![0.5f32; 8]];
        let a = stub_output("demo", &args, 32);
        assert_eq!(a.len(), 32);
        assert_eq!(a, stub_output("demo", &args, 32));
        assert!(a.iter().all(|v| v.is_finite() && (-1.0..1.0).contains(v)));
        // different name or different inputs ⇒ different stream
        assert_ne!(a, stub_output("demo2", &args, 32));
        let other = vec![vec![2.0f32; 8], vec![0.5f32; 8]];
        assert_ne!(a, stub_output("demo", &other, 32));
    }

    #[test]
    fn matches_python_expression() {
        // pinned by python/tests/test_aot.py::test_golden_input_matches_reference_expression
        let v = golden_input(4, -1.0, 1.0, 0);
        let expect = |i: usize| {
            let x = (i as f64 + 1.0) * PHI;
            (-1.0 + 2.0 * (x - x.trunc())) as f32
        };
        for i in 0..4 {
            assert_eq!(v[i], expect(i));
        }
    }

    #[test]
    fn salted_streams_differ() {
        let a = golden_input(16, 0.0, 1.0, 0);
        let b = golden_input(16, 0.0, 1.0, 1);
        assert_ne!(a, b);
        // and are each reproducible
        assert_eq!(b, golden_input(16, 0.0, 1.0, 1));
    }

    #[test]
    fn range_respected() {
        let v = golden_input(1000, 0.0, 1.0, 0);
        assert!(v.iter().all(|&x| (0.0..1.0).contains(&x)));
        // low-discrepancy: mean near 0.5
        let mean: f32 = v.iter().sum::<f32>() / 1000.0;
        assert!((mean - 0.5).abs() < 0.01, "{mean}");
    }

    #[test]
    fn checksum_math() {
        let c = checksum_of(&[1.0, -2.0, 3.0]);
        assert_eq!(c.sum, 2.0);
        assert_eq!(c.abs_sum, 6.0);
        assert_eq!(c.head, vec![1.0, -2.0, 3.0]);
    }

    #[test]
    fn close_to_tolerances() {
        let c = checksum_of(&[1.0, 2.0, 3.0]);
        assert!(c.close_to(6.0, 6.0, &[1.0, 2.0, 3.0], 1e-5));
        assert!(c.close_to(6.0 + 3e-5, 6.0, &[1.0, 2.0, 3.0], 1e-4));
        assert!(!c.close_to(7.0, 6.0, &[1.0, 2.0, 3.0], 1e-5));
        assert!(!c.close_to(6.0, 6.0, &[9.0, 2.0, 3.0], 1e-5));
    }
}
