//! PJRT runtime: executes the AOT-compiled task artifacts (Layer 1/2).
//!
//! `make artifacts` lowers every Table 1 task variant from JAX/Pallas to
//! HLO **text** (see `python/compile/aot.py`); this module loads those
//! files through the `xla` crate's PJRT C API bindings, compiles them
//! once, and executes them on the request path.  Python never runs at
//! serve time.
//!
//! * [`Manifest`] / [`ArtifactSpec`] — parsed `artifacts/manifest.json`.
//! * [`golden_input`] — bit-identical mirror of the Python deterministic
//!   input generator, enabling end-to-end numerics verification against
//!   the manifest's golden checksums.
//! * [`RuntimeClient`] — PJRT CPU client with an executable cache.

mod artifact;
mod client;
mod inputs;

pub use artifact::{ArtifactSpec, Golden, Manifest, TensorSpec};
pub use client::{ExecOutput, RuntimeClient};
pub use inputs::{checksum_of, golden_input, Checksum};
