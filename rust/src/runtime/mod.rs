//! Runtime: executes the AOT-compiled task artifacts (Layer 1/2).
//!
//! `make artifacts` lowers every Table 1 task variant from JAX/Pallas to
//! HLO **text** (see `python/compile/aot.py`); the runtime loads those
//! files, compiles them once, and executes them on the request path.
//! Python never runs at serve time.
//!
//! Two interchangeable backends provide [`RuntimeClient`]:
//!
//! * **stub** (default) — a deterministic in-process executor
//!   (`stub.rs`): no external dependencies, works fully offline, and
//!   serves the built-in synthetic manifest when `artifacts_dir` is the
//!   [`SYNTHETIC_DIR`] sentinel.  Outputs are synthesized, not computed.
//! * **PJRT** (`--features xla`) — the real thing (`client.rs`): HLO
//!   text → `HloModuleProto` → compile → execute through the `xla`
//!   crate's PJRT C API bindings, golden-verified against the manifest.
//!
//! * [`Manifest`] / [`ArtifactSpec`] — parsed `artifacts/manifest.json`
//!   (or [`Manifest::synthetic`]).
//! * [`golden_input`] — bit-identical mirror of the Python deterministic
//!   input generator, enabling end-to-end numerics verification against
//!   the manifest's golden checksums.
//! * [`RuntimeClient`] — backend client with an executable cache.

mod artifact;
#[cfg(feature = "xla")]
mod client;
mod exec;
mod inputs;
#[cfg(not(feature = "xla"))]
mod stub;

/// Sentinel `artifacts_dir` value selecting the built-in synthetic
/// manifest in stub mode (no files on disk required).
pub const SYNTHETIC_DIR: &str = "synthetic";

/// Resolve the default artifacts directory for binaries and examples.
///
/// `$CGRA_MTE_ARTIFACTS` always wins when set.  Under `--features xla`
/// the first of `artifacts/` or `rust/artifacts/` (where `make
/// artifacts` writes when invoked from the workspace root) containing a
/// manifest is used, falling back to `artifacts` so a missing build
/// errors loudly.  The stub backend always defaults to the built-in
/// synthetic manifest: it cannot reproduce a real manifest's golden
/// checksums, so auto-selecting an on-disk build would fail every
/// golden-verified request — loading one anyway requires the env var or
/// an explicit `--artifacts` flag.
pub fn default_artifacts_dir() -> String {
    if let Ok(dir) = std::env::var("CGRA_MTE_ARTIFACTS") {
        return dir;
    }
    if cfg!(feature = "xla") {
        for dir in ["artifacts", "rust/artifacts"] {
            if std::path::Path::new(dir).join("manifest.json").exists() {
                return dir.to_string();
            }
        }
        "artifacts".to_string()
    } else {
        SYNTHETIC_DIR.to_string()
    }
}

pub use artifact::{ArtifactSpec, Golden, Manifest, TensorSpec};
#[cfg(feature = "xla")]
pub use client::RuntimeClient;
pub use exec::ExecOutput;
pub use inputs::{checksum_of, fnv1a, golden_input, stub_output, Checksum};
#[cfg(not(feature = "xla"))]
pub use stub::RuntimeClient;
