//! Execution output type shared by both runtime backends.
//!
//! The PJRT-backed client (`--features xla`) and the default in-process
//! stub executor produce the same [`ExecOutput`], so everything above
//! the runtime boundary (coordinator, benches, examples) is
//! backend-agnostic.

use super::inputs::{checksum_of, Checksum};

/// Output of one artifact execution.
#[derive(Clone, Debug)]
pub struct ExecOutput {
    /// Flattened f32 output values.
    pub values: Vec<f32>,
    /// Expected output shape (from the manifest).
    pub shape: Vec<usize>,
    /// Host wall-clock microseconds for the execute call.
    pub exec_us: f64,
}

impl ExecOutput {
    /// Checksum of the output.
    pub fn checksum(&self) -> Checksum {
        checksum_of(&self.values)
    }
}
