//! PJRT CPU client with an executable cache.
//!
//! Pattern follows /opt/xla-example/load_hlo: HLO *text* → HloModuleProto
//! (the text parser reassigns 64-bit jax ids to parser-local ones the
//! pinned xla_extension 0.5.1 accepts) → XlaComputation → compile →
//! execute.  Artifacts are compiled once and cached; execution is the
//! only per-request cost.

use std::collections::BTreeMap;
use std::time::Instant;

use crate::error::{Error, Result};

use super::artifact::Manifest;
use super::exec::ExecOutput;
use super::inputs::golden_input;

/// PJRT runtime with compile-once executable caching.
pub struct RuntimeClient {
    client: xla::PjRtClient,
    manifest: Manifest,
    executables: BTreeMap<String, xla::PjRtLoadedExecutable>,
    /// compile wall-times per artifact (perf reporting), microseconds.
    compile_us: BTreeMap<String, f64>,
    /// memoized golden argument sets (§Perf L3: the live coordinator
    /// executes on golden inputs per launch; regenerating them per
    /// request wastes ~10-30 µs each).
    golden_cache: BTreeMap<String, Vec<Vec<f32>>>,
}

impl RuntimeClient {
    /// Create a CPU PJRT client over a loaded manifest.
    pub fn new(manifest: Manifest) -> Result<RuntimeClient> {
        let client = xla::PjRtClient::cpu()?;
        Ok(RuntimeClient {
            client,
            manifest,
            executables: BTreeMap::new(),
            compile_us: BTreeMap::new(),
            golden_cache: BTreeMap::new(),
        })
    }

    /// Convenience: load the manifest from a directory and connect.
    pub fn from_dir(dir: impl AsRef<std::path::Path>) -> Result<RuntimeClient> {
        RuntimeClient::new(Manifest::load(dir)?)
    }

    /// PJRT platform name (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// The manifest in use.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Number of compiled executables resident.
    pub fn compiled_count(&self) -> usize {
        self.executables.len()
    }

    /// Compile-time (µs) of an already-compiled artifact.
    pub fn compile_us(&self, name: &str) -> Option<f64> {
        self.compile_us.get(name).copied()
    }

    /// Ensure an artifact is compiled; returns its compile time in µs
    /// (0 if it was already cached).
    pub fn ensure_compiled(&mut self, name: &str) -> Result<f64> {
        if self.executables.contains_key(name) {
            return Ok(0.0);
        }
        let spec = self.manifest.get(name)?.clone();
        let path = self.manifest.hlo_path(&spec);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| Error::Artifact(format!("non-utf8 path {path:?}")))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        let us = t0.elapsed().as_secs_f64() * 1e6;
        self.executables.insert(name.to_string(), exe);
        self.compile_us.insert(name.to_string(), us);
        Ok(us)
    }

    /// Execute an artifact on caller-provided argument tensors (one
    /// flattened f32 buffer per manifest input, in order).
    pub fn execute(&mut self, name: &str, args: &[Vec<f32>]) -> Result<ExecOutput> {
        self.ensure_compiled(name)?;
        let spec = self.manifest.get(name)?.clone();
        if args.len() != spec.inputs.len() {
            return Err(Error::Runtime(format!(
                "{name}: got {} args, artifact expects {}",
                args.len(),
                spec.inputs.len()
            )));
        }
        let mut literals = Vec::with_capacity(args.len());
        for (arg, input) in args.iter().zip(&spec.inputs) {
            if arg.len() != input.elements() {
                return Err(Error::Runtime(format!(
                    "{name}: arg has {} elements, artifact expects {}",
                    arg.len(),
                    input.elements()
                )));
            }
            let dims: Vec<i64> = input.shape.iter().map(|&d| d as i64).collect();
            literals.push(xla::Literal::vec1(arg).reshape(&dims)?);
        }

        let exe = self.executables.get(name).expect("ensured above");
        let t0 = Instant::now();
        let result = exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        let exec_us = t0.elapsed().as_secs_f64() * 1e6;
        // aot.py lowers with return_tuple=True ⇒ 1-tuple output.
        let out = result.to_tuple1()?;
        let values = out.to_vec::<f32>()?;
        if values.len() != spec.output_elements() {
            return Err(Error::Runtime(format!(
                "{name}: output has {} elements, manifest says {}",
                values.len(),
                spec.output_elements()
            )));
        }
        Ok(ExecOutput { values, shape: spec.output_shape.clone(), exec_us })
    }

    /// Synthesize the deterministic argument set for an artifact.
    pub fn golden_args(&self, name: &str) -> Result<Vec<Vec<f32>>> {
        let spec = self.manifest.get(name)?;
        Ok(spec
            .inputs
            .iter()
            .map(|t| golden_input(t.elements(), t.range.0, t.range.1, t.salt))
            .collect())
    }

    /// Execute on the deterministic golden inputs (memoized).
    pub fn execute_golden(&mut self, name: &str) -> Result<ExecOutput> {
        if !self.golden_cache.contains_key(name) {
            let args = self.golden_args(name)?;
            self.golden_cache.insert(name.to_string(), args);
        }
        let args = self.golden_cache.get(name).expect("just inserted").clone();
        self.execute(name, &args)
    }

    /// Execute on golden input and verify against the manifest checksum.
    /// Returns the output on success.
    pub fn verify_golden(&mut self, name: &str) -> Result<ExecOutput> {
        let out = self.execute_golden(name)?;
        let spec = self.manifest.get(name)?;
        let cs = out.checksum();
        if !cs.close_to(spec.golden.sum, spec.golden.abs_sum, &spec.golden.head, 1e-3) {
            return Err(Error::Runtime(format!(
                "{name}: golden mismatch — got sum={:.6} abs={:.6}, manifest sum={:.6} abs={:.6}",
                cs.sum, cs.abs_sum, spec.golden.sum, spec.golden.abs_sum
            )));
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    //! These tests require built artifacts (`make artifacts`); they are
    //! skipped silently when the directory is absent so `cargo test`
    //! stays green on a fresh checkout.
    use super::*;
    use std::path::Path;

    fn artifacts_dir() -> Option<std::path::PathBuf> {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("manifest.json").exists().then_some(dir)
    }

    #[test]
    fn loads_compiles_and_verifies_matmul() {
        let Some(dir) = artifacts_dir() else { return };
        let mut rt = RuntimeClient::from_dir(&dir).unwrap();
        assert!(rt.platform().to_lowercase().contains("cpu") || !rt.platform().is_empty());
        let name = "matmul_128";
        let out = rt.verify_golden(name).unwrap();
        assert_eq!(out.shape, vec![128, 128]);
        assert_eq!(rt.compiled_count(), 1);
        assert!(rt.compile_us(name).unwrap() > 0.0);
        // second call hits the executable cache
        let again = rt.execute_golden(name).unwrap();
        assert_eq!(out.values, again.values);
    }

    #[test]
    fn input_arity_checked() {
        let Some(dir) = artifacts_dir() else { return };
        let mut rt = RuntimeClient::from_dir(&dir).unwrap();
        // wrong arg count
        assert!(rt.execute("matmul_128", &[vec![1.0f32; 3]]).is_err());
        // wrong element count
        assert!(rt
            .execute("matmul_128", &[vec![0.0f32; 3], vec![0.0f32; 3]])
            .is_err());
    }

    #[test]
    fn unknown_artifact_errors() {
        let Some(dir) = artifacts_dir() else { return };
        let mut rt = RuntimeClient::from_dir(&dir).unwrap();
        assert!(rt.execute_golden("no_such_artifact").is_err());
    }
}
