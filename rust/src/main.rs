//! cgra-mte — leader entrypoint + CLI.
//!
//! Subcommands (hand-rolled parsing; `clap` is unavailable offline):
//!
//! ```text
//! cgra-mte simulate-cloud [--policy P] [--duration-ms N] [--seed S] [--config F]
//! cgra-mte simulate-edge  [--policy P] [--frames N] [--seed S] [--config F]
//! cgra-mte serve          [--requests N] [--artifacts DIR]
//! cgra-mte verify-artifacts [--artifacts DIR]
//! cgra-mte table1
//! cgra-mte render-arch
//! ```

use cgra_mte::config::{presets, Config, RegionPolicyKind, WorkloadConfig};
use cgra_mte::coordinator::{Leader, TenantId};
use cgra_mte::metrics::Table;
use cgra_mte::sim::{run_cloud, run_edge};
use cgra_mte::tasks::{AppId, TaskLibrary};
use cgra_mte::util::logging;
use cgra_mte::util::rng::Rng;

fn main() {
    logging::init();
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            2
        }
    };
    std::process::exit(code);
}

fn run(args: &[String]) -> cgra_mte::Result<()> {
    let Some(cmd) = args.first() else {
        print_usage();
        return Ok(());
    };
    let flags = Flags::parse(&args[1..])?;
    match cmd.as_str() {
        "simulate-cloud" => simulate_cloud(&flags),
        "simulate-edge" => simulate_edge(&flags),
        "serve" => serve(&flags),
        "serve-tcp" => serve_tcp(&flags),
        "sweep" => sweep(&flags),
        "verify-artifacts" => verify_artifacts(&flags),
        "table1" => table1(),
        "render-arch" => render_arch(),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => Err(cgra_mte::Error::Config(format!("unknown subcommand '{other}'"))),
    }
}

fn print_usage() {
    println!(
        "cgra-mte — multi-task execution on CGRAs (paper reproduction)\n\
         \n\
         USAGE: cgra-mte <subcommand> [flags]\n\
         \n\
         SUBCOMMANDS\n\
           simulate-cloud     cloud scenario (§3.1 / Fig. 4)\n\
           simulate-edge      autonomous scenario (§3.2 / Fig. 5)\n\
           serve              live coordinator: schedule + execute artifacts\n\
           serve-tcp          concurrent TCP front (--bind 127.0.0.1:7070):\n\
                              SUBMIT/STATS/QUIT/SHUTDOWN, BUSY backpressure\n\
           verify-artifacts   golden-check every AOT artifact via PJRT\n\
           table1             print the Table 1 task library\n\
           render-arch        render the CGRA tile array (Fig. 1)\n\
           sweep              load-calibration sweep (EXPERIMENTS.md Fig. 4)\n\
         \n\
         FLAGS\n\
           --policy P         baseline | fixed | variable | flexible (default flexible)\n\
           --duration-ms N    cloud arrival window (default 10000)\n\
           --frames N         edge frames (default 600)\n\
           --seed S           workload RNG seed\n\
           --requests N       serve: number of requests (default 12)\n\
           --artifacts DIR    artifacts directory (default: artifacts/ if built,\n\
                              else the stub backend's built-in 'synthetic' set)\n\
           --config F         TOML config file (overrides defaults; an [energy]\n\
                              section arms accounting/gating/power-cap governor)\n\
           --export FILE      write per-request/per-frame CSV (simulate-*)\n\
           --export-energy F  write energy_json when [energy].enabled (simulate-*)\n\
           --bind ADDR        serve-tcp bind address (default 127.0.0.1:7070)\n\
           --workers N        serve-tcp scheduler workers (default 2)\n\
           --queue-depth N    serve-tcp per-tenant admission queue depth (default 32)\n\
           --shards N         serve-tcp fabric-pool shard count (default 1)\n\
           --placement P      serve-tcp pool placement: least-loaded | best-fit |\n\
                              sticky | energy-aware\n\
           --mode M           serve-tcp front: threaded | reactor (default threaded)\n\
           --protocol P       serve-tcp wire protocol: auto | text | binary\n\
                              (binary requires --mode reactor)\n\
           --idle-timeout-ms N  serve-tcp reactor idle-connection sweep (0 = off)\n\
           --dump-metrics F   serve-tcp: write a final flight record (obs on) or\n\
                              metrics exposition (obs off) to F on shutdown"
    );
}

/// Minimal --key value flag parser.
struct Flags {
    pairs: Vec<(String, String)>,
}

impl Flags {
    fn parse(args: &[String]) -> cgra_mte::Result<Flags> {
        let mut pairs = Vec::new();
        let mut i = 0;
        while i < args.len() {
            let key = args[i]
                .strip_prefix("--")
                .ok_or_else(|| cgra_mte::Error::Config(format!("expected --flag, got '{}'", args[i])))?;
            let value = args
                .get(i + 1)
                .ok_or_else(|| cgra_mte::Error::Config(format!("--{key} needs a value")))?;
            pairs.push((key.to_string(), value.clone()));
            i += 2;
        }
        Ok(Flags { pairs })
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.pairs
            .iter()
            .rev()
            .find(|(k, _)| k == key)
            .map(|(_, v)| v.as_str())
    }

    fn get_u64(&self, key: &str) -> cgra_mte::Result<Option<u64>> {
        self.get(key)
            .map(|v| {
                v.parse::<u64>()
                    .map_err(|_| cgra_mte::Error::Config(format!("--{key} must be an integer")))
            })
            .transpose()
    }

    fn policy(&self) -> cgra_mte::Result<RegionPolicyKind> {
        match self.get("policy") {
            Some(name) => RegionPolicyKind::from_name(name),
            None => Ok(RegionPolicyKind::FlexibleShape),
        }
    }

    fn base_config(&self, default: Config) -> cgra_mte::Result<Config> {
        match self.get("config") {
            Some(path) => Config::from_file(path),
            None => Ok(default),
        }
    }
}

/// Shared `--export-energy` handling for the simulate commands.
fn export_energy_json(
    flags: &Flags,
    energy: &cgra_mte::energy::EnergyReport,
) -> cgra_mte::Result<()> {
    if let Some(path) = flags.get("export-energy") {
        cgra_mte::metrics::export::write_file(
            path,
            &cgra_mte::metrics::export::energy_json(energy),
        )?;
        println!("wrote energy JSON to {path}");
    }
    Ok(())
}

fn simulate_cloud(flags: &Flags) -> cgra_mte::Result<()> {
    let policy = flags.policy()?;
    let mut cfg = flags.base_config(presets::cloud_scenario(policy))?;
    cfg.scheduler.region_policy = policy;
    if let WorkloadConfig::Cloud(ref mut c) = cfg.workload {
        if let Some(d) = flags.get_u64("duration-ms")? {
            c.duration_ms = d as f64;
        }
        if let Some(s) = flags.get_u64("seed")? {
            c.seed = s;
        }
    }
    let report = run_cloud(&cfg)?;
    let mut table = Table::new(
        format!("cloud scenario — {} regions", policy.name()),
        &["app", "requests", "mean NTAT", "svc tput (u/cyc)"],
    );
    let ntat = report.ntat.mean_ntat();
    let tput = report.throughput.service_throughput();
    for app in AppId::ALL {
        table.row(&[
            app.name().to_string(),
            report.ntat.count(app).to_string(),
            format!("{:.3}", ntat.get(&app).copied().unwrap_or(0.0)),
            format!("{:.2}", tput.get(&app).copied().unwrap_or(0.0)),
        ]);
    }
    print!("{}", table.render());
    if let Some(path) = flags.get("export") {
        cgra_mte::metrics::export::write_file(path, &cgra_mte::metrics::export::ntat_csv(&report.ntat))?;
        println!("wrote per-request CSV to {path}");
    }
    println!(
        "completed {}/{} requests; array util {:.1}%; glb util {:.1}%; dpr hit-rate {:.0}%",
        report.completed,
        report.submitted,
        report.array_utilization * 100.0,
        report.glb_utilization * 100.0,
        report.dpr_stats.hit_rate() * 100.0,
    );
    if let Some(ref energy) = report.energy {
        println!(
            "energy: {:.4} J total (mean {:.3} W, peak window {:.3} W); \
             gated {:.4} J, idle {:.4} J, wakes {}, throttled {}",
            energy.total_j,
            energy.mean_watts,
            energy.peak_window_watts,
            energy.gated_j,
            energy.idle_j,
            energy.wakes,
            energy.throttled,
        );
        export_energy_json(flags, energy)?;
    }
    print_qos(report.qos.as_ref(), cfg.arch.core_clock_mhz);
    Ok(())
}

/// Render the per-class SLO summary when the QoS subsystem is on.
fn print_qos(qos: Option<&cgra_mte::qos::QosReport>, clock_mhz: u32) {
    let Some(qos) = qos else { return };
    let cycles_per_ms = clock_mhz as f64 * 1e3;
    for row in &qos.per_class {
        if row.completed == 0 {
            continue;
        }
        println!(
            "qos[{}]: completed {}, missed {}/{} (miss rate {:.3}), p50 {:.3} ms, p99 {:.3} ms",
            row.class.name(),
            row.completed,
            row.missed,
            row.deadlined,
            row.miss_rate(),
            row.p50_latency / cycles_per_ms,
            row.p99_latency / cycles_per_ms,
        );
    }
    println!(
        "qos: {} preemption passes, {} victims evicted, {} resumed ({} cycles charged)",
        qos.preemptions, qos.victims_evicted, qos.victims_resumed, qos.preempt_cycles,
    );
}

fn simulate_edge(flags: &Flags) -> cgra_mte::Result<()> {
    let policy = flags.policy()?;
    let mut cfg = flags.base_config(presets::edge_scenario(policy))?;
    cfg.scheduler.region_policy = policy;
    if let WorkloadConfig::Edge(ref mut e) = cfg.workload {
        if let Some(f) = flags.get_u64("frames")? {
            e.frames = f as u32;
        }
        if let Some(s) = flags.get_u64("seed")? {
            e.seed = s;
        }
    }
    let report = run_edge(&cfg)?;
    let clk = cfg.arch.core_clock_mhz;
    println!(
        "edge scenario — {} regions, {:?} DPR\n\
         frames: {}   event requests: {}\n\
         mean latency: {:.3} ms   (reconfig {:.1}%, wait+exec {:.1}%)\n\
         p99 latency: {:.3} ms",
        report.policy.name(),
        report.dpr_mode,
        report.frames,
        report.event_requests,
        report.mean_latency_ms(clk),
        report.latency.reconfig_share() * 100.0,
        (1.0 - report.latency.reconfig_share()) * 100.0,
        report.latency.p99_total() / (clk as f64 * 1e3),
    );
    if let Some(path) = flags.get("export") {
        cgra_mte::metrics::export::write_file(
            path,
            &cgra_mte::metrics::export::latency_csv(&report.latency),
        )?;
        println!("wrote per-frame CSV to {path}");
    }
    if let Some(ref energy) = report.energy {
        println!(
            "energy: {:.4} J total (mean {:.3} W, peak window {:.3} W); wakes {}",
            energy.total_j, energy.mean_watts, energy.peak_window_watts, energy.wakes,
        );
        export_energy_json(flags, energy)?;
    }
    print_qos(report.qos.as_ref(), clk);
    Ok(())
}

/// Resolve the artifacts directory: explicit flag wins; otherwise the
/// shared env-var / built-tree / synthetic-fallback resolution.
fn resolve_artifacts_dir(flag: Option<&str>) -> String {
    match flag {
        Some(dir) => dir.to_string(),
        None => cgra_mte::runtime::default_artifacts_dir(),
    }
}

fn serve(flags: &Flags) -> cgra_mte::Result<()> {
    let mut cfg = flags.base_config(presets::paper_default())?;
    cfg.artifacts_dir = resolve_artifacts_dir(flags.get("artifacts"));
    let n = flags.get_u64("requests")?.unwrap_or(12);
    let mut leader = Leader::new(&cfg)?;
    println!("warmup: compiled all artifacts in {:.0} ms", leader.stats().warmup_ms);

    // synth a mixed submission batch: tenants round-robin, 2ms apart
    let mut rng = Rng::new(flags.get_u64("seed")?.unwrap_or(42));
    let cycles_per_ms = cfg.arch.core_clock_mhz as u64 * 1000;
    let subs: Vec<(TenantId, AppId, u64)> = (0..n)
        .map(|i| {
            let tenant = (i % 4) as u32;
            let jitter = rng.below(cycles_per_ms);
            (TenantId(tenant), AppId::ALL[tenant as usize], i * 2 * cycles_per_ms + jitter)
        })
        .collect();
    let stats = leader.serve(&subs)?;
    let mut table = Table::new(
        "served requests",
        &["seq", "tenant", "app", "TAT (ms)", "NTAT", "compute (µs)", "output Σ"],
    );
    for o in &stats.outcomes {
        table.row(&[
            o.seq.to_string(),
            o.tenant.0.to_string(),
            o.app.name().to_string(),
            format!("{:.3}", o.tat_cycles as f64 / cycles_per_ms as f64),
            format!("{:.2}", o.ntat),
            format!("{:.0}", o.compute_us),
            format!("{:+.3}", o.final_output_sum),
        ]);
    }
    print!("{}", table.render());
    println!(
        "launches: {}   total PJRT compute: {:.1} ms",
        stats.launches,
        stats.total_compute_us / 1e3
    );
    Ok(())
}

/// Load-calibration sweep: baseline vs flexible across arrival scales —
/// regenerates the table EXPERIMENTS.md's Fig. 4 calibration came from.
fn sweep(flags: &Flags) -> cgra_mte::Result<()> {
    let duration = flags.get_u64("duration-ms")?.unwrap_or(3000) as f64;
    let base_rates = [45.0, 25.0, 30.0, 28.0];
    let mut table = Table::new(
        "load sweep — mean NTAT and flexible:baseline ratios",
        &["arrival scale", "base NTAT", "flex NTAT", "NTAT ratio", "tput ratio (mean)"],
    );
    for scale in [2.0, 1.5, 1.0, 0.75, 0.5] {
        let mut results = Vec::new();
        for policy in [RegionPolicyKind::Baseline, RegionPolicyKind::FlexibleShape] {
            let mut cfg = presets::cloud_scenario(policy);
            if let WorkloadConfig::Cloud(ref mut c) = cfg.workload {
                c.duration_ms = duration;
                for (slot, base) in c.mean_interarrival_ms.iter_mut().zip(base_rates) {
                    *slot = base * scale;
                }
            }
            results.push(run_cloud(&cfg)?);
        }
        let (base, flex) = (&results[0], &results[1]);
        let bt = base.throughput.service_throughput();
        let ft = flex.throughput.service_throughput();
        let tput_ratio = AppId::ALL
            .iter()
            .map(|a| ft.get(a).copied().unwrap_or(0.0) / bt.get(a).copied().unwrap_or(1.0).max(1e-12))
            .sum::<f64>()
            / 4.0;
        table.row(&[
            format!("{scale:.2}x"),
            format!("{:.2}", base.mean_ntat_across_apps()),
            format!("{:.2}", flex.mean_ntat_across_apps()),
            format!("{:.2}", flex.mean_ntat_across_apps() / base.mean_ntat_across_apps()),
            format!("{tput_ratio:.2}x"),
        ]);
    }
    print!("{}", table.render());
    println!("scale 1.00x is the Fig. 4 calibration point (see EXPERIMENTS.md §Notes).");
    Ok(())
}

fn serve_tcp(flags: &Flags) -> cgra_mte::Result<()> {
    let mut cfg = flags.base_config(presets::paper_default())?;
    cfg.artifacts_dir = resolve_artifacts_dir(flags.get("artifacts"));
    if let Some(w) = flags.get_u64("workers")? {
        cfg.server.workers = w as u32;
    }
    if let Some(d) = flags.get_u64("queue-depth")? {
        cfg.server.queue_depth = d as u32;
    }
    if let Some(s) = flags.get_u64("shards")? {
        cfg.pool.shards = s as u32;
    }
    if let Some(p) = flags.get("placement") {
        cfg.pool.placement = cgra_mte::config::PlacementPolicyKind::from_name(p)?;
    }
    if let Some(m) = flags.get("mode") {
        cfg.server.mode = cgra_mte::config::ServerModeKind::from_name(m)?;
    }
    if let Some(p) = flags.get("protocol") {
        cfg.server.protocol = cgra_mte::config::WireProtocolKind::from_name(p)?;
    }
    if let Some(t) = flags.get_u64("idle-timeout-ms")? {
        cfg.server.idle_timeout_ms = t;
    }
    cfg.validate()?;
    let bind = flags.get("bind").unwrap_or("127.0.0.1:7070");
    let dump = flags.get("dump-metrics").map(std::path::PathBuf::from);
    println!("compiling artifacts + binding {bind} ...");
    let server = cgra_mte::coordinator::Server::start_with_dump(&cfg, bind, dump)?;
    println!(
        "listening on {} — {} front ({} wire), {} workers, queue depth {} per tenant, {} fabric shard(s) ({})\n\
         protocol: SUBMIT <tenant 0-3> <resnet18|mobilenet|camera|harris|pipeline> | STATS [tenant|SHARDS] | METRICS |\n\
         EXPLAIN <req> | WATCH | DUMP | DEFRAG | QUIT | SHUTDOWN",
        server.addr,
        cfg.server.mode.name(),
        cfg.server.protocol.name(),
        cfg.server.workers,
        cfg.server.queue_depth,
        cfg.pool.shards,
        cfg.pool.placement.name()
    );
    println!("send SHUTDOWN to stop gracefully (Ctrl-C terminates without draining)");
    server.wait();
    println!("server drained and shut down cleanly");
    Ok(())
}

fn verify_artifacts(flags: &Flags) -> cgra_mte::Result<()> {
    let dir = resolve_artifacts_dir(flags.get("artifacts"));
    let mut rt = cgra_mte::runtime::RuntimeClient::from_dir(&dir)?;
    if !rt.manifest().is_synthetic() {
        rt.manifest().verify_files()?;
    }
    let names: Vec<String> = rt.manifest().iter().map(|a| a.name.clone()).collect();
    let mut failures = 0;
    for name in &names {
        match rt.verify_golden(name) {
            Ok(out) => println!("OK   {name:<24} exec={:>8.0} µs", out.exec_us),
            Err(e) => {
                failures += 1;
                println!("FAIL {name:<24} {e}");
            }
        }
    }
    if failures > 0 {
        return Err(cgra_mte::Error::Artifact(format!("{failures} artifacts failed verification")));
    }
    println!("all {} artifacts verified", names.len());
    Ok(())
}

fn table1() -> cgra_mte::Result<()> {
    let lib = TaskLibrary::table1();
    let mut table = Table::new(
        "Table 1 — task variants",
        &["task", "ver", "tpt (u/cyc)", "array slices", "GLB slices", "work/invocation", "artifact"],
    );
    for t in lib.iter() {
        for v in &t.variants {
            table.row(&[
                t.id.to_string(),
                v.ver.to_string(),
                format!("{}", v.throughput),
                v.demand.array_slices.to_string(),
                v.demand.glb_slices.to_string(),
                format!("{} {}", t.work, t.unit.name()),
                v.artifact.clone().unwrap_or_default(),
            ]);
        }
    }
    print!("{}", table.render());
    Ok(())
}

fn render_arch() -> cgra_mte::Result<()> {
    let arch = cgra_mte::config::ArchConfig::default();
    let geom = cgra_mte::arch::Geometry::new(&arch)?;
    println!(
        "CGRA {}x{} — {} PE, {} MEM tiles; {} GLB banks x {} KiB; {} array-slices ({} cols each)",
        arch.cols,
        arch.rows,
        arch.pe_tiles(),
        arch.mem_tiles(),
        arch.glb_banks,
        arch.glb_bank_kib,
        arch.array_slices(),
        arch.slice_cols,
    );
    print!("{}", geom.render());
    Ok(())
}
