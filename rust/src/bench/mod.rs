//! Mini benchmarking harness (`criterion` is unavailable offline).
//!
//! [`Bencher`] runs warmup + timed samples of a closure and reports
//! mean/median/σ/min; bench binaries (`benches/*.rs`, `harness = false`)
//! use it together with [`crate::metrics::Table`] to print the paper's
//! tables and figures.

use std::time::Instant;

use crate::util::stats::Summary;

/// Tiny JSON writer for machine-readable bench reports (`BENCH_*.json`).
///
/// Bench binaries print human tables; the perf-trajectory tooling wants
/// a stable JSON file per bench so results are comparable across PRs.
/// Values are pre-rendered JSON fragments — use [`jsonw::str_val`],
/// [`jsonw::num_f`], [`jsonw::num_u`], [`jsonw::bool_val`],
/// [`jsonw::arr`], and [`jsonw::obj`] to build them; everything round-
/// trips through [`crate::util::json::Json`].
pub mod jsonw {
    /// Escape a string for a JSON string literal.
    pub fn esc(s: &str) -> String {
        let mut out = String::with_capacity(s.len());
        for c in s.chars() {
            match c {
                '"' => out.push_str("\\\""),
                '\\' => out.push_str("\\\\"),
                '\n' => out.push_str("\\n"),
                '\r' => out.push_str("\\r"),
                '\t' => out.push_str("\\t"),
                c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
                c => out.push(c),
            }
        }
        out
    }

    /// A JSON string value.
    pub fn str_val(s: &str) -> String {
        format!("\"{}\"", esc(s))
    }

    /// A JSON number from a float (non-finite values become `null`).
    pub fn num_f(x: f64) -> String {
        if x.is_finite() {
            format!("{x:.6}")
        } else {
            "null".into()
        }
    }

    /// A JSON number from an unsigned integer.
    pub fn num_u(x: u64) -> String {
        x.to_string()
    }

    /// A JSON boolean.
    pub fn bool_val(b: bool) -> String {
        b.to_string()
    }

    /// A JSON array of pre-rendered values.
    pub fn arr(items: &[String]) -> String {
        format!("[{}]", items.join(","))
    }

    /// A JSON object of (key, pre-rendered value) pairs.
    pub fn obj(fields: &[(&str, String)]) -> String {
        let body: Vec<String> =
            fields.iter().map(|(k, v)| format!("\"{}\":{}", esc(k), v)).collect();
        format!("{{{}}}", body.join(","))
    }
}

/// Result of one benchmark.
#[derive(Clone, Debug)]
pub struct BenchResult {
    /// Benchmark label.
    pub name: String,
    /// Sample count.
    pub samples: usize,
    /// Mean per-iteration time, nanoseconds.
    pub mean_ns: f64,
    /// Median per-iteration time, nanoseconds.
    pub median_ns: f64,
    /// Standard deviation, nanoseconds.
    pub stddev_ns: f64,
    /// Minimum, nanoseconds.
    pub min_ns: f64,
}

impl BenchResult {
    /// Human-readable time formatting.
    pub fn fmt_ns(ns: f64) -> String {
        if ns >= 1e9 {
            format!("{:.2} s", ns / 1e9)
        } else if ns >= 1e6 {
            format!("{:.2} ms", ns / 1e6)
        } else if ns >= 1e3 {
            format!("{:.2} µs", ns / 1e3)
        } else {
            format!("{ns:.0} ns")
        }
    }

    /// One-line summary.
    pub fn line(&self) -> String {
        format!(
            "{:<40} {:>12} ±{:>10}  (median {:>12}, min {:>12}, n={})",
            self.name,
            Self::fmt_ns(self.mean_ns),
            Self::fmt_ns(self.stddev_ns),
            Self::fmt_ns(self.median_ns),
            Self::fmt_ns(self.min_ns),
            self.samples
        )
    }
}

/// Benchmark runner with warmup and adaptive batching.
#[derive(Clone, Debug)]
pub struct Bencher {
    /// Warmup iterations before sampling.
    pub warmup_iters: u32,
    /// Timed samples to collect.
    pub samples: u32,
    /// Iterations per sample (amortizes timer overhead); 0 = auto.
    pub iters_per_sample: u32,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher { warmup_iters: 3, samples: 20, iters_per_sample: 0 }
    }
}

impl Bencher {
    /// Quick preset for slow end-to-end benches.
    pub fn quick() -> Self {
        Bencher { warmup_iters: 1, samples: 5, iters_per_sample: 1 }
    }

    /// Run `f` and report statistics.  `f` should return something so
    /// the optimizer can't elide it; the value is black-boxed.
    pub fn run<T>(&self, name: &str, mut f: impl FnMut() -> T) -> BenchResult {
        for _ in 0..self.warmup_iters {
            std::hint::black_box(f());
        }
        // auto-batch: target ≥ 100 µs per sample
        let iters = if self.iters_per_sample > 0 {
            self.iters_per_sample
        } else {
            let t0 = Instant::now();
            std::hint::black_box(f());
            let one = t0.elapsed().as_nanos().max(1) as u64;
            ((100_000 / one).clamp(1, 10_000)) as u32
        };
        let mut summary = Summary::new();
        for _ in 0..self.samples {
            let t0 = Instant::now();
            for _ in 0..iters {
                std::hint::black_box(f());
            }
            summary.add(t0.elapsed().as_nanos() as f64 / iters as f64);
        }
        let mut s = summary.clone();
        BenchResult {
            name: name.to_string(),
            samples: s.count(),
            mean_ns: s.mean(),
            median_ns: s.median(),
            stddev_ns: s.stddev(),
            min_ns: s.min(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_a_cheap_closure() {
        let b = Bencher { warmup_iters: 1, samples: 5, iters_per_sample: 100 };
        let r = b.run("noop-ish", || 1 + 1);
        assert_eq!(r.samples, 5);
        assert!(r.mean_ns >= 0.0);
        assert!(r.min_ns <= r.mean_ns + 1.0);
    }

    #[test]
    fn fmt_ns_scales() {
        assert_eq!(BenchResult::fmt_ns(500.0), "500 ns");
        assert_eq!(BenchResult::fmt_ns(1500.0), "1.50 µs");
        assert_eq!(BenchResult::fmt_ns(2.5e6), "2.50 ms");
        assert_eq!(BenchResult::fmt_ns(3.2e9), "3.20 s");
    }

    #[test]
    fn line_contains_name() {
        let b = Bencher { warmup_iters: 0, samples: 2, iters_per_sample: 10 };
        let r = b.run("my-bench", || 42);
        assert!(r.line().contains("my-bench"));
    }

    #[test]
    fn jsonw_output_round_trips_through_parser() {
        use super::jsonw::*;
        let doc = obj(&[
            ("bench", str_val("ablation_migration")),
            ("smoke", bool_val(true)),
            ("seeds", arr(&[num_u(11), num_u(23)])),
            ("util", num_f(0.625)),
            ("nan_guard", num_f(f64::NAN)),
            ("label", str_val("quote \" backslash \\ tab\t")),
            (
                "rows",
                arr(&[obj(&[("defrag", str_val("off")), ("nofit", num_u(42))])]),
            ),
        ]);
        let v = crate::util::json::Json::parse(&doc).unwrap();
        assert_eq!(v.get("bench").and_then(|b| b.as_str()), Some("ablation_migration"));
        assert_eq!(v.get("seeds").map(|s| s.items().len()), Some(2));
        assert_eq!(v.req_f64("util").unwrap(), 0.625);
        assert_eq!(v.get("nan_guard"), Some(&crate::util::json::Json::Null));
        let rows = v.get("rows").unwrap().items();
        assert_eq!(rows[0].req_f64("nofit").unwrap(), 42.0);
        assert_eq!(
            rows[0].get("defrag").and_then(|d| d.as_str()),
            Some("off")
        );
    }
}
