//! The scheduler core: policy-driven variant selection + region binding.

use std::collections::{BTreeMap, BTreeSet};

use crate::abstraction::{SliceDemand, SliceRange};
use crate::compiler::generate_bitstream;
use crate::config::{
    Config, DefragPolicyKind, NocPlacementKind, QosClass, QosConfig, QosPolicyKind,
    RegionPolicyKind, SchedulerPolicyKind,
};
use crate::dpr::{Bitstream, BitstreamId, DprEngine, DprMode};
use crate::energy::{EnergyAccountant, EnergyModel, EnergyReport};
use crate::error::{Error, Result};
use crate::migration::{
    execute_plan, CompactionPlan, DefragPlanner, MigrationCostModel, MigrationReport,
    MigrationStats,
};
use crate::noc::{ContentionModel, NocReport, NocStats};
use crate::obs::{
    AltVerdict, Decision, DecisionKind, JournalKind, MetricsRegistry, VariantAlt, VictimRank,
};
use crate::qos::{self, PreemptionRecord, QosStats, VictimCandidate};
use crate::regions::{AllocOutcome, ExecutionRegion, RegionId, RegionManager};
use crate::tasks::{TaskId, TaskInstanceId, TaskLibrary, VariantId};

use super::queue::{ReadyTask, RequestQueue};

/// One successfully launched task instance.
#[derive(Clone, Debug)]
pub struct Launch {
    /// Which instance.
    pub instance: TaskInstanceId,
    /// Task id.
    pub task: TaskId,
    /// Chosen variant.
    pub ver: VariantId,
    /// Allocated region.
    pub region: RegionId,
    /// Replication factor (fixed-size unrolling; 1 otherwise).
    pub replicas: u32,
    /// Launch cycle.
    pub start: u64,
    /// Reconfiguration cycles charged before execution (includes the
    /// compaction-pass wait when a defragmentation rescued this launch).
    pub dpr_cycles: u64,
    /// Execution cycles (work / effective throughput).
    pub exec_cycles: u64,
    /// `start + dpr_cycles + exec_cycles`.
    pub finish: u64,
    /// Whether the bitstream was GLB-resident (fast-DPR hit).
    pub cache_hit: bool,
    /// Whether this launch resumes a checkpointed (preempted) instance
    /// — its state is restored, not recomputed, so the functional layer
    /// must not execute the artifact again ([`crate::qos`]).
    pub resumed: bool,
}

/// A variant option considered by the policy, with effective throughput.
#[derive(Clone, Copy, Debug)]
struct Option_ {
    ver: VariantId,
    eff_throughput: f64,
    /// Replication request (fixed-size only; 0 = plain allocation).
    replicate: u32,
    /// Fall back to exclusive whole-machine allocation.
    exclusive: bool,
}

/// Provenance view of one preference-order option
/// ([`crate::obs::provenance`]).
fn alt_of(opt: &Option_, verdict: AltVerdict) -> VariantAlt {
    VariantAlt {
        ver: opt.ver.0,
        score: opt.eff_throughput,
        replicate: opt.replicate,
        verdict,
    }
}

/// What draining one queued completion event resolved to
/// ([`Scheduler::drain_completion`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CompletionOutcome {
    /// The event was invalidated by a preemption; drop it.
    Cancelled,
    /// A migration pushed the finish out past the event's cycle;
    /// re-queue at the carried authoritative finish.
    Stale(u64),
    /// The task genuinely finished: region freed, instance returned.
    Done(TaskInstanceId),
}

/// Attempt outcome of placing one ready task.
enum Attempt {
    /// Placed and charged.
    Launched(Launch),
    /// At least one variant could fit *eventually* but not right now —
    /// the defragmentation trigger.  Carries every blocked variant in
    /// policy-preference order: the planner rescues the most-preferred
    /// one that compaction can actually make room for (a full fabric
    /// often cannot host the fastest variant but can host a smaller one).
    Blocked { options: Vec<(VariantId, SliceDemand)> },
    /// No variant can ever fit in the current machine state class.
    Impossible,
}

/// A launched task's live bookkeeping (completion + migration identity).
#[derive(Clone, Debug)]
struct RunningTask {
    inst: TaskInstanceId,
    task: TaskId,
    ver: VariantId,
    /// Submitting tenant (energy attribution).
    tenant: u32,
    /// QoS class (preemption eligibility; [`crate::qos`]).
    class: QosClass,
    /// Absolute deadline, if any (victim-selection ordering).
    deadline: Option<u64>,
    /// Authoritative completion cycle.  Migrations push this out; the
    /// sims re-validate queued completion events against it (lazy
    /// rescheduling), so timelines stay correct without retracting
    /// events from the queue.
    finish: u64,
}

/// State saved for a preempted task awaiting resume ([`crate::qos`]).
#[derive(Clone, Debug)]
struct Checkpoint {
    task: TaskId,
    ver: VariantId,
    tenant: u32,
    class: QosClass,
    deadline: Option<u64>,
    /// Exact footprint the task held (resume re-allocates this shape).
    demand: SliceDemand,
    /// Execution cycles still owed at eviction time.
    remaining: u64,
}

/// Event-driven scheduler implementing the paper's greedy policy plus
/// FCFS and fair-share ablations, with optional live-migration
/// defragmentation ([`crate::migration`]).
#[derive(Clone, Debug)]
pub struct Scheduler {
    lib: TaskLibrary,
    mgr: RegionManager,
    dpr: DprEngine,
    policy: SchedulerPolicyKind,
    baseline_single_mapping: bool,
    /// region → live task, for completion handling and migration.
    running: BTreeMap<RegionId, RunningTask>,
    /// fair-share rotation cursor.
    rr_cursor: u32,
    /// pre-generated bitstreams per (task, variant).
    bitstreams: BTreeMap<BitstreamId, Bitstream>,
    /// Variant options per task in policy preference order, precomputed
    /// at construction — every input ([`TaskLibrary`] demands and
    /// throughputs, mechanism geometry, energy model) is config-time
    /// constant, so the per-launch enumeration + sort is paid once.
    options: BTreeMap<TaskId, Vec<Option_>>,
    /// Defragmentation planner (off unless `scheduler.defrag_policy`).
    planner: DefragPlanner,
    /// Migration cycle pricing.
    cost_model: MigrationCostModel,
    /// Cumulative migration counters.
    mig_stats: MigrationStats,
    /// Cycles a just-committed compaction charges to the next launch
    /// (the rescued task waits for the whole migration pass).
    pending_migration_cycles: u64,
    /// Energy accountant + power-cap governor ([`crate::energy`]); a
    /// no-op unless `[energy].enabled`.
    meter: EnergyAccountant,
    /// Wake latency charged (like DPR cycles) to a launch that wakes
    /// power-gated domains; 0 unless gating is armed.
    wake_cycles: u64,
    /// GLB bank capacity in bytes (migration copy energy).
    glb_bank_bytes: u64,
    /// QoS knobs ([`crate::qos`]); every QoS path is gated on
    /// `qos.enabled`.
    qos: QosConfig,
    /// Checkpointed (preempted) instances awaiting resume.
    checkpoints: BTreeMap<TaskInstanceId, Checkpoint>,
    /// Regions whose queued completion events were invalidated by an
    /// eviction — drivers consume these via
    /// [`Scheduler::take_cancelled`] and drop the stale event.
    cancelled: BTreeSet<RegionId>,
    /// Cumulative preemption counters.
    qos_stats: QosStats,
    /// Evictions since the last [`Scheduler::take_preemptions`] drain.
    preempt_log: Vec<PreemptionRecord>,
    /// Cycles the current schedule step's preemption pass charges to
    /// the rescued launch (victims checkpoint in parallel: the max).
    pending_preempt_cycles: u64,
    /// NoC contention pricing ([`crate::noc`]); identity with `[noc]`
    /// disabled.
    noc_model: ContentionModel,
    /// Feed producer-affinity hints into placement (`[noc]`
    /// `stream_affinity` under comm-aware placement).
    noc_affinity: bool,
    /// Cumulative NoC counters (advanced only while corridor tracking
    /// is armed).
    noc_stats: NocStats,
    /// request seq → array-slice start of its most recently completed
    /// node — the producer position a consumer launch is pulled toward.
    /// Bounded (oldest request pruned) so long runs cannot grow it.
    affinity: BTreeMap<u64, u32>,
    /// Journal instants (defrag passes, migrations) awaiting a
    /// [`Scheduler::take_obs_events`] drain; never populated unless
    /// `obs_armed` ([`crate::obs`]).
    obs_log: Vec<(u64, JournalKind)>,
    /// Whether an observability context is listening.
    obs_armed: bool,
    /// Decision-provenance records awaiting a
    /// [`Scheduler::take_decisions`] drain; never populated unless
    /// `prov_armed` ([`crate::obs::provenance`]).
    prov_log: Vec<Decision>,
    /// Whether a decision-provenance ring is listening.
    prov_armed: bool,
}

/// Producer-affinity table bound: requests tracked at once.  4096 open
/// pipelines per shard is far past every preset; the bound only guards
/// against pathological drivers that never complete requests.
const AFFINITY_CAP: usize = 4096;

impl Scheduler {
    /// Build from a config; `mode` selects the DPR path (Fig. 5 compares
    /// AXI4-Lite for the baseline vs fast-DPR for the mechanisms).
    pub fn new(cfg: &Config, lib: TaskLibrary, mode: DprMode) -> Scheduler {
        let mut mgr = RegionManager::new(&cfg.arch, &cfg.scheduler);
        let gating = cfg.energy.enabled && cfg.energy.gating;
        mgr.set_gating(gating, cfg.energy.gate_min_run);
        if cfg.noc.enabled {
            mgr.set_noc(&cfg.arch, cfg.noc.placement == NocPlacementKind::CommAware);
        }
        let mut planner = DefragPlanner::new(&cfg.scheduler);
        planner.set_comm_aware(cfg.noc.enabled && cfg.noc.defrag_align);
        let dpr = DprEngine::new(&cfg.arch, &cfg.dpr, mode);
        let mut bitstreams = BTreeMap::new();
        for t in lib.iter() {
            for v in &t.variants {
                let bs = generate_bitstream(&t.id.0, v.ver.0, &v.demand, &cfg.arch, &cfg.dpr);
                bitstreams.insert(bs.id.clone(), bs);
            }
        }
        let mut sched = Scheduler {
            lib,
            mgr,
            dpr,
            policy: cfg.scheduler.policy,
            baseline_single_mapping: cfg.scheduler.baseline_single_mapping,
            running: BTreeMap::new(),
            rr_cursor: 0,
            bitstreams,
            options: BTreeMap::new(),
            planner,
            cost_model: MigrationCostModel::new(&cfg.arch, cfg.scheduler.migration_cost_model),
            mig_stats: MigrationStats::default(),
            pending_migration_cycles: 0,
            meter: EnergyAccountant::new(
                EnergyModel::new(&cfg.arch, &cfg.energy),
                cfg.energy.enabled,
            ),
            wake_cycles: if gating { cfg.energy.wake_cycles } else { 0 },
            glb_bank_bytes: cfg.arch.glb_slice_bytes(),
            qos: cfg.qos.clone(),
            checkpoints: BTreeMap::new(),
            cancelled: BTreeSet::new(),
            qos_stats: QosStats::default(),
            preempt_log: Vec::new(),
            pending_preempt_cycles: 0,
            noc_model: ContentionModel::new(&cfg.arch, &cfg.noc),
            noc_affinity: cfg.noc.enabled
                && cfg.noc.stream_affinity
                && cfg.noc.placement == NocPlacementKind::CommAware,
            noc_stats: NocStats::default(),
            affinity: BTreeMap::new(),
            obs_log: Vec::new(),
            obs_armed: false,
            prov_log: Vec::new(),
            prov_armed: false,
        };
        let ids: Vec<TaskId> = sched.lib.iter().map(|t| t.id.clone()).collect();
        for id in ids {
            let opts = sched.options_for(&id);
            sched.options.insert(id, opts);
        }
        sched
    }

    /// Task library in use.
    pub fn library(&self) -> &TaskLibrary {
        &self.lib
    }

    /// Region manager (metrics want utilization/fragmentation).
    pub fn regions(&self) -> &RegionManager {
        &self.mgr
    }

    /// DPR engine (cache stats).
    pub fn dpr(&self) -> &DprEngine {
        &self.dpr
    }

    /// Preload every variant's bitstream into the GLB cache — the
    /// paper's "pre-load bitstreams of the next task in advance".
    pub fn preload_all(&mut self) {
        let all: Vec<Bitstream> = self.bitstreams.values().cloned().collect();
        for bs in &all {
            self.dpr.preload(bs);
        }
    }

    /// Integrate the energy accountant up to `now` under the *current*
    /// allocation state — called at the top of every state-changing
    /// entry point, so power is integrated piecewise-constant between
    /// discrete events (exactly).
    fn advance_energy(&mut self, now: u64) {
        if self.meter.enabled() {
            // one gated walk per event: idle is its free-count complement
            let gated = self.mgr.gated_counts();
            let idle = (
                self.mgr.glb_map().free_count() - gated.0,
                self.mgr.array_map().free_count() - gated.1,
            );
            self.meter.advance(now, idle, gated);
        }
    }

    /// The energy accountant (read side: totals, windowed power).
    pub fn energy(&self) -> &EnergyAccountant {
        &self.meter
    }

    /// Final energy report, integrated up to `now` (`None` when
    /// `[energy]` accounting is disabled).
    pub fn energy_report(&mut self, now: u64) -> Option<EnergyReport> {
        self.advance_energy(now);
        self.meter.report()
    }

    /// Marginal pJ/cycle this fabric would add by hosting `demand` —
    /// the energy-aware pool placement score ([`crate::fabric`]).
    /// Reads 0 with `[energy]` accounting off, so an `energy-aware`
    /// placement policy degenerates to least-loaded order exactly as
    /// documented instead of consolidating on the default model costs.
    pub fn marginal_placement_pj(&self, demand: &SliceDemand) -> f64 {
        if !self.meter.enabled() {
            return 0.0;
        }
        self.meter.model().marginal_placement_pj(
            demand,
            self.mgr.idle_free_counts(),
            self.running.is_empty(),
        )
    }

    /// Steady-state draw of one variant option: `demand` slices
    /// computing per replica, with the held footprint an exclusive or
    /// replicated allocation would over-hold at idle rates.  The single
    /// source of truth for both the power-cap governor's admission
    /// projection and the energy-aware policy's EDP ranking — they must
    /// never disagree on an option's power.
    fn option_power(
        &self,
        demand: SliceDemand,
        replicate: u32,
        exclusive: bool,
    ) -> crate::energy::ActivePower {
        let r = replicate.max(1);
        let active = demand.scaled(r);
        let held = if exclusive {
            SliceDemand::new(self.mgr.glb_map().len(), self.mgr.array_map().len())
        } else if replicate > 1 {
            self.mgr.unit().scaled(r)
        } else {
            demand
        };
        self.meter.model().region_power(&active, &held)
    }

    /// Scheduling step: launch every ready task that can be placed.
    /// Called on arrival and completion events.
    pub fn schedule(&mut self, queue: &mut RequestQueue, now: u64) -> Vec<Launch> {
        self.advance_energy(now);
        // Empty-frontier fast path: nothing to order or place.  The
        // fair-share cursor still advances exactly as on the slow path,
        // so the rotation phase is independent of backlog shape.
        if queue.ready_count() == 0 {
            if self.policy == SchedulerPolicyKind::FairShare {
                self.rr_cursor = self.rr_cursor.wrapping_add(1);
            }
            return Vec::new();
        }
        // Single pass: no completions happen inside a step, so resource
        // availability only shrinks — a task that failed to place cannot
        // succeed later in the same step, and tasks are independent.
        // (§Perf L3: a rescan-after-every-launch variant was O(ready²)
        // and dominated heavy-backlog simulations.)
        let ready = self.order_ready(queue.ready_tasks(), queue.tenant_span(), now);
        let mut launches = Vec::new();
        for rt in ready {
            match self.try_launch(&rt, now) {
                Attempt::Launched(launch) => {
                    queue.mark_launched(rt.instance).expect("ready implies launchable");
                    launches.push(launch);
                }
                Attempt::Blocked { options } => {
                    // Free slices exist but not contiguously: before
                    // leaving the task waiting, ask the defragmentation
                    // planner whether compacting the running regions
                    // frees room, and retry once if a plan committed.
                    self.mig_stats.nofit_events += 1;
                    let mut rescued = false;
                    if self.planner.enabled() && self.try_defrag_for(&rt, &options, now) {
                        if let Attempt::Launched(launch) = self.try_launch(&rt, now) {
                            self.mig_stats.rescued_launches += 1;
                            queue
                                .mark_launched(rt.instance)
                                .expect("ready implies launchable");
                            launches.push(launch);
                            rescued = true;
                        }
                        self.pending_migration_cycles = 0; // consumed or dropped
                    }
                    // Compaction could not (or may not) help: a
                    // higher-class task may checkpoint-and-evict
                    // running strictly-lower-class tasks instead
                    // ([`crate::qos`]).
                    if !rescued && self.try_preempt_for(&rt, &options, queue, now) {
                        if let Attempt::Launched(launch) = self.try_launch(&rt, now) {
                            self.qos_stats.rescued_by_preemption += 1;
                            queue
                                .mark_launched(rt.instance)
                                .expect("ready implies launchable");
                            launches.push(launch);
                        }
                        self.pending_preempt_cycles = 0; // consumed or dropped
                    }
                }
                Attempt::Impossible => {}
            }
        }
        if self.policy == SchedulerPolicyKind::FairShare {
            self.rr_cursor = self.rr_cursor.wrapping_add(1);
        }
        launches
    }

    /// Drain one queued completion event for `region` in a single pass:
    /// consume a pending cancellation marker, re-validate the event
    /// against the authoritative finish cycle (migrations push
    /// completions out after their events were queued), and only then
    /// commit the completion.  One scheduler entry point instead of the
    /// `take_cancelled` → [`Scheduler::finish_of`] →
    /// [`Scheduler::complete`] triple every driver used to chain —
    /// same observable outcomes, one lookup walk.
    pub fn drain_completion(&mut self, region: RegionId, now: u64) -> Result<CompletionOutcome> {
        if self.cancelled.remove(&region) {
            return Ok(CompletionOutcome::Cancelled);
        }
        if let Some(rt) = self.running.get(&region) {
            if rt.finish > now {
                return Ok(CompletionOutcome::Stale(rt.finish));
            }
        }
        self.complete(region, now).map(CompletionOutcome::Done)
    }

    /// Handle a task completion at cycle `now`: free its region (energy
    /// is integrated up to `now` before the power state changes).
    /// Returns the instance that was running there.
    pub fn complete(&mut self, region: RegionId, now: u64) -> Result<TaskInstanceId> {
        self.advance_energy(now);
        let rt = self
            .running
            .remove(&region)
            .ok_or_else(|| Error::Sched(format!("completion for idle region {region}")))?;
        // Remember where this request's stage ran (read before release —
        // the region is gone afterwards): its successors stream their
        // input from here, so placement pulls them toward this column.
        if self.noc_affinity {
            if let Some(start) = self
                .mgr
                .region(region)
                .and_then(|r| r.array.first())
                .map(|a| a.start)
            {
                self.affinity.insert(rt.inst.request, start);
                while self.affinity.len() > AFFINITY_CAP {
                    self.affinity.pop_first();
                }
            }
        }
        self.meter.on_complete(region);
        self.mgr.release(region)?;
        self.dpr.unpin(&BitstreamId::new(rt.task.0.clone(), rt.ver.0));
        Ok(rt.inst)
    }

    /// Authoritative completion cycle of the task on `region`, if any.
    ///
    /// Migrations extend finish times after the Launch was emitted, so a
    /// driver popping a completion event must re-validate it here and
    /// re-queue at the returned cycle when it is still in the future
    /// (lazy event rescheduling).
    pub fn finish_of(&self, region: RegionId) -> Option<u64> {
        self.running.get(&region).map(|r| r.finish)
    }

    /// Cumulative migration/defragmentation counters.
    pub fn migration_stats(&self) -> MigrationStats {
        self.mig_stats
    }

    /// Whether the defragmentation planner is active
    /// (`scheduler.defrag_policy` ≠ off).  The fabric pool consults this
    /// before attempting a cross-shard rescue compaction.
    pub fn defrag_enabled(&self) -> bool {
        self.planner.enabled()
    }

    /// Force one compaction pass right now (the coordinator's `DEFRAG`
    /// wire command) — ignores the defrag threshold and needs no blocked
    /// task.  Running tasks that move are charged their migration cycles.
    pub fn defrag_now(&mut self, now: u64) -> MigrationReport {
        self.advance_energy(now);
        let frag_before = self.mgr.fragmentation();
        let (migrated, cycles) = match self.planner.compact(&self.mgr) {
            None => (0, 0),
            Some(plan) => {
                let costs = self.step_costs(&plan);
                self.commit_plan(&plan, &costs, now).unwrap_or((0, 0))
            }
        };
        MigrationReport { migrated, cycles, frag_before, frag_after: self.mgr.fragmentation() }
    }

    /// Number of running tasks.
    pub fn running_count(&self) -> usize {
        self.running.len()
    }

    /// End-of-run NoC summary (`None` unless `[noc]` is enabled).
    pub fn noc_report(&self) -> Option<NocReport> {
        let map = self.mgr.corridor_map()?;
        Some(self.noc_stats.report(map.corridors(), map.capacity()))
    }

    // ----------------------------------------------------------------- obs

    /// Arm (or disarm) collection of journal instants for the `[obs]`
    /// subsystem.  Disarmed (the default) the scheduler records
    /// nothing — the zero-overhead guarantee for obs-off runs.
    pub fn set_obs(&mut self, armed: bool) {
        self.obs_armed = armed;
    }

    /// Drain the journal instants (defrag passes, task migrations)
    /// recorded since the last call.  Always empty while disarmed.
    pub fn take_obs_events(&mut self) -> Vec<(u64, JournalKind)> {
        std::mem::take(&mut self.obs_log)
    }

    /// Arm (or disarm) decision-provenance collection.  Disarmed (the
    /// default) no choice point records anything — the same
    /// zero-overhead guarantee as [`Scheduler::set_obs`].
    pub fn set_provenance(&mut self, armed: bool) {
        self.prov_armed = armed;
    }

    /// Drain the decision records (variant selection, NoFit causes,
    /// preemption rankings, defrag accept/reject) accumulated since the
    /// last call.  Always empty while disarmed.
    pub fn take_decisions(&mut self) -> Vec<Decision> {
        std::mem::take(&mut self.prov_log)
    }

    /// Export cumulative subsystem counters into an observability
    /// registry (`[obs]`): DPR cache, migration/defrag engine, QoS
    /// preemptor, NoC model and energy accountant.  `shard` labels
    /// every series when this scheduler runs inside a pool shard.
    pub fn export_metrics(&self, reg: &MetricsRegistry, shard: Option<u32>) {
        let shard_label = shard.map(|s| s.to_string());
        let mut base: Vec<(&str, &str)> = Vec::new();
        if let Some(s) = shard_label.as_deref() {
            base.push(("shard", s));
        }
        let cache = self.dpr.cache().stats();
        reg.set_counter("cgra_dpr_cache_hits_total", &base, cache.hits);
        reg.set_counter("cgra_dpr_cache_misses_total", &base, cache.misses);
        reg.set_counter("cgra_dpr_cache_evictions_total", &base, cache.evictions);
        let m = &self.mig_stats;
        reg.set_counter("cgra_mig_nofit_events_total", &base, m.nofit_events);
        reg.set_counter("cgra_mig_plans_committed_total", &base, m.plans_committed);
        reg.set_counter("cgra_mig_tasks_migrated_total", &base, m.tasks_migrated);
        reg.set_counter("cgra_mig_cycles_total", &base, m.migration_cycles);
        reg.set_counter("cgra_mig_rescued_launches_total", &base, m.rescued_launches);
        let q = &self.qos_stats;
        reg.set_counter("cgra_qos_preemptions_total", &base, q.preemptions);
        reg.set_counter("cgra_qos_victims_evicted_total", &base, q.victims_evicted);
        reg.set_counter("cgra_qos_victims_resumed_total", &base, q.victims_resumed);
        reg.set_counter("cgra_qos_preempt_cycles_total", &base, q.preempt_cycles);
        let n = &self.noc_stats;
        reg.set_counter("cgra_noc_streams_placed_total", &base, n.streams_placed);
        reg.set_counter("cgra_noc_contended_launches_total", &base, n.contended_launches);
        reg.set_counter("cgra_noc_contention_cycles_total", &base, n.contention_cycles);
        if self.meter.enabled() {
            reg.set_gauge("cgra_energy_joules_total", &base, self.meter.total_joules());
        }
        let (ug, ua) = self.mgr.utilization();
        reg.set_gauge("cgra_sched_glb_utilization", &base, ug);
        reg.set_gauge("cgra_sched_array_utilization", &base, ua);
    }

    // ----------------------------------------------------------------- qos

    /// Cumulative preemption counters ([`crate::qos`]).
    pub fn qos_stats(&self) -> QosStats {
        self.qos_stats
    }

    /// Checkpointed (evicted, not yet resumed) instances.
    pub fn checkpointed_count(&self) -> usize {
        self.checkpoints.len()
    }

    /// Whether `region`'s queued completion event was invalidated by a
    /// preemption.  Consumes the marker: a driver popping a completion
    /// event calls this first and drops the event when it returns true.
    /// Always false with the QoS subsystem disabled (the set stays
    /// empty), so existing drivers keep their strict invariant checks.
    pub fn take_cancelled(&mut self, region: RegionId) -> bool {
        self.cancelled.remove(&region)
    }

    /// Drain the evictions performed since the last call (trace lines +
    /// property checks in the drivers).
    pub fn take_preemptions(&mut self) -> Vec<PreemptionRecord> {
        std::mem::take(&mut self.preempt_log)
    }

    /// Longest remaining runway (cycles past `now`) over running tasks
    /// of class strictly below `class` — the class-aware pool placement
    /// signal ([`crate::fabric`]): a Critical request avoids shards
    /// where long-runway BestEffort work would stand in its way.
    pub fn lower_class_runway(&self, class: QosClass, now: u64) -> u64 {
        self.running
            .values()
            .filter(|r| r.class < class)
            .map(|r| r.finish.saturating_sub(now))
            .max()
            .unwrap_or(0)
    }

    /// Provenance: a refused / blocked resume attempt.  A checkpoint
    /// carries exactly one saved variant, so the record is a one-alt
    /// NoFit naming the root cause.
    fn resume_nofit(&mut self, rt: &ReadyTask, ck: &Checkpoint, now: u64, verdict: AltVerdict) {
        if !self.prov_armed {
            return;
        }
        self.prov_log.push(Decision::new(
            now,
            rt.instance.request,
            DecisionKind::NoFit {
                task: ck.task.0.clone(),
                alts: vec![VariantAlt { ver: ck.ver.0, score: 0.0, replicate: 0, verdict }],
            },
        ));
    }

    /// Resume a checkpointed instance: re-allocate its saved footprint,
    /// restream its saved variant (fast-DPR; the bitstream stayed
    /// pinned), pay the GLB state copy-in, and run the remaining
    /// cycles.
    fn try_resume(&mut self, rt: &ReadyTask, ck: &Checkpoint, now: u64) -> Attempt {
        // Power governor: a resume is still a launch.  Refused options
        // are not `Blocked` — neither compaction nor preemption can
        // create power headroom.
        if self.meter.enabled() {
            let projected = self.option_power(ck.demand, 0, false);
            if !self.meter.admits(&projected) {
                self.resume_nofit(rt, ck, now, AltVerdict::PowerCap);
                return Attempt::Impossible;
            }
        }
        let region: ExecutionRegion = match self.mgr.try_allocate(&ck.demand) {
            AllocOutcome::Allocated(r) => r,
            AllocOutcome::NoFit => {
                self.resume_nofit(rt, ck, now, AltVerdict::NoFitSlices);
                return Attempt::Blocked { options: vec![(ck.ver, ck.demand)] };
            }
            AllocOutcome::NeverFits => {
                self.resume_nofit(rt, ck, now, AltVerdict::NeverFits);
                return Attempt::Impossible;
            }
        };
        let bs_id = BitstreamId::new(ck.task.0.clone(), ck.ver.0);
        let bs = self.bitstreams.get(&bs_id).expect("pre-generated");
        let dest = region.array.first().copied().unwrap_or(SliceRange::empty());
        let dpr_out = self.dpr.reconfigure(bs, &dest);
        let bs_words = bs.words;
        let restore = self.cost_model.resume_extra_cycles();
        let woken = region.woken();
        let wake = if woken.0 + woken.1 > 0 { self.wake_cycles } else { 0 };
        let dpr_cycles = dpr_out.cycles
            + restore
            + wake
            + self.pending_migration_cycles
            + self.pending_preempt_cycles;
        self.pending_migration_cycles = 0;
        self.pending_preempt_cycles = 0;
        // The remaining cycles were already contention-charged at the
        // original launch — re-charging them here would compound the
        // bill.  The *energy* duty does track the new placement: the
        // resumed region streams at whatever its new corridors grant.
        let exec_cycles = ck.remaining;
        let slowdown = self.mgr.corridor_slowdown(region.id);
        let finish = now + dpr_cycles + exec_cycles;

        self.meter.on_launch(
            region.id,
            &ck.demand,
            &region.footprint(),
            &ck.task.0,
            ck.tenant,
            bs_words,
            dpr_out.cache_hit,
            woken,
            self.noc_model.duty_scale(slowdown),
        );
        if self.mgr.noc_enabled() {
            // a resume re-lands the stream on corridors; nothing new is
            // charged (cycles were priced at the original launch)
            self.noc_stats.on_launch(slowdown, 0, 0, false);
        }
        if self.meter.enabled() && restore > 0 {
            // GLB state copy-in, energy-accounted like a migration's
            // bank copy
            let pj = self
                .meter
                .model()
                .migration_step_pj(0, ck.demand.glb_slices as u64 * self.glb_bank_bytes);
            self.meter.on_migration(pj, 0.0, &ck.task.0, ck.tenant);
        }
        // no new pin: the resumed launch inherits the pin its original
        // launch took (evictions keep it), so pins stay balanced against
        // the single unpin at completion
        self.qos_stats.victims_resumed += 1;
        self.qos_stats.preempt_cycles += restore;
        if self.prov_armed {
            self.prov_log.push(Decision::new(
                now,
                rt.instance.request,
                DecisionKind::Variant {
                    task: ck.task.0.clone(),
                    chosen: ck.ver.0,
                    replicas: 1,
                    score: 0.0,
                    resumed: true,
                    alts: vec![VariantAlt {
                        ver: ck.ver.0,
                        score: 0.0,
                        replicate: 0,
                        verdict: AltVerdict::Chosen,
                    }],
                },
            ));
        }
        self.checkpoints.remove(&rt.instance);
        self.running.insert(
            region.id,
            RunningTask {
                inst: rt.instance,
                task: ck.task.clone(),
                ver: ck.ver,
                tenant: ck.tenant,
                class: ck.class,
                deadline: ck.deadline,
                finish,
            },
        );
        Attempt::Launched(Launch {
            instance: rt.instance,
            task: ck.task.clone(),
            ver: ck.ver,
            region: region.id,
            replicas: 1,
            start: now,
            dpr_cycles,
            exec_cycles,
            finish,
            cache_hit: dpr_out.cache_hit,
            resumed: true,
        })
    }

    /// Checkpoint-and-evict running strictly-lower-class tasks so one
    /// of `rt`'s blocked variants can fit.  Returns whether any victims
    /// were evicted (the caller then retries the launch, which waits
    /// out the checkpoint window via `pending_preempt_cycles`).
    fn try_preempt_for(
        &mut self,
        rt: &ReadyTask,
        options: &[(VariantId, SliceDemand)],
        queue: &mut RequestQueue,
        now: u64,
    ) -> bool {
        // Preemption requires the EDF policy: `policy = "fifo"` is the
        // documented scheduling-neutral baseline (classes tracked for
        // SLO only), so it must never evict regardless of the
        // `preemption` knob's default.
        if !self.qos.enabled
            || !self.qos.preemption
            || self.qos.policy != QosPolicyKind::Edf
        {
            return false;
        }
        // Baseline's whole-machine regions have nothing to carve out,
        // and replicated fixed-size regions resume with a different
        // replica count (a different effective throughput): both are
        // excluded as victims, the former wholesale.
        if self.mgr.policy() == RegionPolicyKind::Baseline {
            return false;
        }
        let mut candidates: Vec<VictimCandidate> = Vec::new();
        for (&region, r) in self.running.iter() {
            if r.class >= rt.class || r.finish <= now {
                continue;
            }
            // evictable = a plain contiguous region whose footprint the
            // mechanism can re-allocate later (this excludes fixed-size
            // exclusive whole-machine fallbacks, whose footprint no unit
            // can ever host again)
            let movable = self
                .mgr
                .region(region)
                .map(|reg| {
                    reg.replicas <= 1
                        && reg.is_contiguous()
                        && self.mgr.can_ever_fit(&reg.footprint())
                })
                .unwrap_or(false);
            if movable {
                candidates.push(VictimCandidate {
                    region,
                    class: r.class,
                    deadline: r.deadline,
                    remaining: r.finish.saturating_sub(now),
                });
            }
        }
        if candidates.is_empty() {
            return false;
        }
        qos::eviction_order(&mut candidates);
        // Blocked options carry single-copy demands.  That is sound for
        // every mechanism: fixed-size replication launches with however
        // many units are free (≥ 1), so freeing one copy's worth always
        // rescues the launch, and an exclusive option's oversized demand
        // simply never passes the probe (no victim is evicted for it).
        // One reusable scratch probe serves every option's dry run —
        // the selection never clones the region manager.
        let mut probe = self.mgr.fit_probe();
        let mut selected = None;
        for (_, demand) in options {
            if let Some(victims) = qos::select_victims(
                &mut probe,
                &candidates,
                demand,
                self.qos.max_victims as usize,
            ) {
                selected = Some(victims);
                break;
            }
        }
        drop(probe);
        if self.prov_armed {
            let chosen: &[RegionId] = selected.as_deref().unwrap_or(&[]);
            let ranks: Vec<VictimRank> = candidates
                .iter()
                .map(|c| VictimRank {
                    region: c.region.0,
                    class: c.class.name(),
                    remaining: c.remaining,
                    evicted: chosen.contains(&c.region),
                })
                .collect();
            self.prov_log.push(Decision::new(
                now,
                rt.instance.request,
                DecisionKind::Preempt {
                    task: rt.task.0.clone(),
                    candidates: ranks,
                    evicted: chosen.len() as u32,
                },
            ));
        }
        let Some(victims) = selected else {
            return false;
        };
        // commit: checkpoint every victim; they quiesce in
        // parallel, so the rescued launch waits out the longest
        // checkpoint, not the sum
        let mut pass_cycles = 0u64;
        for region in victims {
            match self.evict(region, rt, queue, now) {
                Ok(cycles) => pass_cycles = pass_cycles.max(cycles),
                Err(_) => {
                    debug_assert!(false, "victim {region} was not evictable");
                }
            }
        }
        self.pending_preempt_cycles = pass_cycles;
        self.qos_stats.preemptions += 1;
        true
    }

    /// Checkpoint one victim off `region`: stop its energy draw, charge
    /// the checkpoint (quiesce + GLB copy-out), free the region, park
    /// the instance back on the ready frontier, and invalidate its
    /// queued completion event.  Returns the checkpoint cycles charged.
    fn evict(
        &mut self,
        region: RegionId,
        preemptor: &ReadyTask,
        queue: &mut RequestQueue,
        now: u64,
    ) -> Result<u64> {
        let victim = self
            .running
            .remove(&region)
            .ok_or_else(|| Error::Sched(format!("eviction of idle region {region}")))?;
        debug_assert!(
            victim.class < preemptor.class,
            "preemption must be strictly class-ascending"
        );
        let footprint = self
            .mgr
            .region(region)
            .map(|r| r.footprint())
            .unwrap_or_else(|| SliceDemand::new(0, 0));
        let remaining = victim.finish.saturating_sub(now).max(1);
        let cycles = self.cost_model.checkpoint_cycles();
        self.meter.on_complete(region);
        if self.meter.enabled() {
            // GLB state copy-out, energy-accounted like a migration's
            // bank copy (no restream: nothing is reinstalled yet)
            let pj = self
                .meter
                .model()
                .migration_step_pj(0, footprint.glb_slices as u64 * self.glb_bank_bytes);
            self.meter.on_migration(pj, 0.0, &victim.task.0, victim.tenant);
        }
        self.mgr.release(region)?;
        // deliberately NOT unpinned: the checkpoint's fast-DPR relaunch
        // depends on the bitstream staying GLB-resident across the
        // eviction window; the pin transfers to the resumed launch and
        // drops at its completion
        queue.mark_preempted(victim.inst, now)?;
        self.cancelled.insert(region);
        self.checkpoints.insert(
            victim.inst,
            Checkpoint {
                task: victim.task.clone(),
                ver: victim.ver,
                tenant: victim.tenant,
                class: victim.class,
                deadline: victim.deadline,
                demand: footprint,
                remaining,
            },
        );
        self.qos_stats.victims_evicted += 1;
        self.qos_stats.preempt_cycles += cycles;
        self.preempt_log.push(PreemptionRecord {
            victim: victim.inst,
            victim_task: victim.task,
            victim_class: victim.class,
            victim_region: region,
            preemptor: preemptor.instance,
            preemptor_class: preemptor.class,
            remaining_cycles: remaining,
            checkpoint_cycles: cycles,
        });
        Ok(cycles)
    }

    // ------------------------------------------------------------- policy

    /// Order the ready list according to the task-selection policy.
    /// With the QoS subsystem enabled under its EDF policy, class order
    /// (strict), deadlines (EDF within class) and BestEffort aging take
    /// precedence over the base policy's ordering ([`crate::qos`]).
    fn order_ready(&self, ready: Vec<ReadyTask>, tenant_span: u32, now: u64) -> Vec<ReadyTask> {
        if self.qos.enabled && self.qos.policy == QosPolicyKind::Edf {
            return qos::order_ready(ready, now, self.qos.aging_cycles);
        }
        let mut ready = ready;
        match self.policy {
            // arrival order (request seq, then node) — queue order.
            SchedulerPolicyKind::GreedyThroughput
            | SchedulerPolicyKind::FcfsFirstFit
            | SchedulerPolicyKind::EnergyAware => ready,
            SchedulerPolicyKind::FairShare => {
                // rotate tenants so each gets the head slot in turn.
                // The modulus is the submitted tenant-id span, derived
                // from the queue — a hard-coded `% 4` made any 5th
                // tenant alias onto tenant 0's rotation slot.
                let n = tenant_span.max(1);
                let cursor = self.rr_cursor % n;
                ready.sort_by_key(|r| ((r.tenant % n + n - cursor) % n, r.instance));
                ready
            }
            SchedulerPolicyKind::ShortestJobFirst => {
                // shortest minimum execution time first; arrival breaks ties
                ready.sort_by_key(|r| {
                    let est = self
                        .lib
                        .get(&r.task)
                        .map(|t| t.exec_cycles(t.fastest()))
                        .unwrap_or(u64::MAX);
                    (est, r.instance)
                });
                ready
            }
        }
    }

    /// Enumerate variant options for a task in policy preference order.
    fn options_for(&self, task: &TaskId) -> Vec<Option_> {
        let spec = match self.lib.get(task) {
            Ok(s) => s,
            Err(_) => return Vec::new(),
        };
        let mut opts: Vec<Option_> = Vec::new();
        match self.mgr.policy() {
            RegionPolicyKind::Baseline => {
                // Whole machine per task.  With `baseline_single_mapping`
                // (the embedded Fig. 5 baseline) only the standard
                // variant-a bitstream exists; otherwise the baseline may
                // use any pre-compiled mapping (the generous cloud
                // baseline — keeps Fig. 4 margins conservative).
                if self.baseline_single_mapping {
                    let v = spec.smallest();
                    opts.push(Option_ {
                        ver: v.ver,
                        eff_throughput: v.throughput,
                        replicate: 0,
                        exclusive: true,
                    });
                } else {
                    for v in &spec.variants {
                        opts.push(Option_ {
                            ver: v.ver,
                            eff_throughput: v.throughput,
                            replicate: 0,
                            exclusive: true,
                        });
                    }
                }
            }
            RegionPolicyKind::FixedSize => {
                let unit = self.mgr.unit();
                let best_tpt = spec.fastest().throughput;
                for v in &spec.variants {
                    if v.demand.fits_within(&unit) {
                        opts.push(Option_ {
                            ver: v.ver,
                            eff_throughput: v.throughput,
                            replicate: 0,
                            exclusive: false,
                        });
                        // replication option: unroll copies across units
                        // up to the best pre-compiled mapping's speedup
                        // (no point unrolling beyond what variant b/c
                        // achieves with optimization).
                        let cap = (best_tpt / v.throughput).ceil() as u32;
                        if cap > 1 {
                            opts.push(Option_ {
                                ver: v.ver,
                                eff_throughput: v.throughput * cap as f64,
                                replicate: cap,
                                exclusive: false,
                            });
                        }
                    }
                }
                if opts.is_empty() {
                    // fits no unit: exclusive whole-machine fallback with
                    // every variant as a candidate.
                    for v in &spec.variants {
                        opts.push(Option_ {
                            ver: v.ver,
                            eff_throughput: v.throughput,
                            replicate: 0,
                            exclusive: true,
                        });
                    }
                }
            }
            RegionPolicyKind::VariableSize | RegionPolicyKind::FlexibleShape => {
                for v in &spec.variants {
                    opts.push(Option_ {
                        ver: v.ver,
                        eff_throughput: v.throughput,
                        replicate: 0,
                        exclusive: false,
                    });
                }
            }
        }
        match self.policy {
            SchedulerPolicyKind::GreedyThroughput
            | SchedulerPolicyKind::FairShare
            | SchedulerPolicyKind::ShortestJobFirst => {
                // paper: highest throughput first.  `total_cmp` keeps the
                // sort total even for NaN throughputs (a degenerate
                // zero-work variant used to panic `partial_cmp`'s unwrap).
                opts.sort_by(|a, b| b.eff_throughput.total_cmp(&a.eff_throughput));
            }
            SchedulerPolicyKind::FcfsFirstFit => {
                // smallest footprint first (ascending throughput proxy)
                opts.sort_by(|a, b| a.eff_throughput.total_cmp(&b.eff_throughput));
            }
            SchedulerPolicyKind::EnergyAware => {
                // minimal energy-delay product first: EDP(v) = P(v)·t(v)²
                // under the [`crate::energy::EnergyModel`]; highest
                // throughput, then variant letter, break ties.  Keys are
                // computed once per option, not inside the comparator.
                let mut keyed: Vec<(f64, Option_)> = opts
                    .into_iter()
                    .map(|o| {
                        let v = spec.variant(o.ver).expect("option from spec");
                        let power =
                            self.option_power(v.demand, o.replicate, o.exclusive).total();
                        let t = spec.work as f64 / o.eff_throughput;
                        (power * t * t, o)
                    })
                    .collect();
                keyed.sort_by(|(ea, a), (eb, b)| {
                    ea.total_cmp(eb)
                        .then(b.eff_throughput.total_cmp(&a.eff_throughput))
                        .then(a.ver.0.cmp(&b.ver.0))
                });
                opts = keyed.into_iter().map(|(_, o)| o).collect();
            }
        }
        opts
    }

    /// Try to launch one ready task.  A checkpointed (preempted)
    /// instance takes the resume path instead: its saved variant, its
    /// saved footprint, its remaining cycles.
    fn try_launch(&mut self, rt: &ReadyTask, now: u64) -> Attempt {
        if let Some(ck) = self.checkpoints.get(&rt.instance).cloned() {
            return self.try_resume(rt, &ck, now);
        }
        // cached preference order (`Option_` is `Copy`: the clone is a
        // flat memcpy, not a re-enumeration + sort per attempt)
        let options = match self.options.get(&rt.task) {
            Some(opts) => opts.clone(),
            None => self.options_for(&rt.task),
        };
        // Producer-affinity hint: pull a consumer stage toward the array
        // columns where its request's previous stage just ran, so its
        // corridor span overlaps the banks its input bytes sit in.
        let hint = if self.noc_affinity && rt.stream_in_bytes > 0 {
            self.affinity.get(&rt.instance.request).copied()
        } else {
            None
        };
        let mut blocked: Vec<(VariantId, SliceDemand)> = Vec::new();
        // Provenance: verdict per walked option, in preference order
        // (empty and never pushed to while disarmed).
        let mut alts: Vec<VariantAlt> = Vec::new();
        for (idx, &opt) in options.iter().enumerate() {
            let spec = self.lib.get(&rt.task).expect("options imply spec");
            let variant = spec.variant(opt.ver).expect("option from spec").clone();
            // Power-cap governor: refuse options whose projected draw
            // would push the fabric over `[energy].power_cap_watts`
            // (conservative: charges the full requested replication).
            // Throttled options are not `blocked` — compaction cannot
            // create power headroom, only completions can.
            if self.meter.enabled() {
                let projected =
                    self.option_power(variant.demand, opt.replicate, opt.exclusive);
                if !self.meter.admits(&projected) {
                    if self.prov_armed {
                        alts.push(alt_of(&opt, AltVerdict::PowerCap));
                    }
                    continue;
                }
            }
            let outcome = if opt.exclusive {
                self.mgr.try_allocate_exclusive(&variant.demand)
            } else if opt.replicate > 1 {
                self.mgr.try_allocate_replicated(&variant.demand, opt.replicate)
            } else {
                self.mgr.try_allocate_hinted(&variant.demand, hint)
            };
            let region: ExecutionRegion = match outcome {
                AllocOutcome::Allocated(r) => r,
                AllocOutcome::NoFit => {
                    // remember blocked variants (in preference order):
                    // they are what a compaction should make room for
                    blocked.push((opt.ver, variant.demand));
                    if self.prov_armed {
                        alts.push(alt_of(&opt, AltVerdict::NoFitSlices));
                    }
                    continue;
                }
                AllocOutcome::NeverFits => {
                    if self.prov_armed {
                        alts.push(alt_of(&opt, AltVerdict::NeverFits));
                    }
                    continue;
                }
            };

            // DPR: stream the variant's bitstream into the region
            // (borrowed in place — the bitstream table and the DPR
            // engine are disjoint fields, so no per-launch clone).
            let bs_id = BitstreamId::new(rt.task.0.clone(), opt.ver.0);
            let bs = self.bitstreams.get(&bs_id).expect("pre-generated");
            let dest = region.array.first().copied().unwrap_or(SliceRange::empty());
            let dpr_out = self.dpr.reconfigure(bs, &dest);
            let bs_words = bs.words;

            let replicas = region.replicas.max(1);
            let eff_tpt = variant.throughput * replicas as f64;
            let base_exec = (spec.work as f64 / eff_tpt).ceil() as u64;
            // Contention sample: worst oversubscription along the
            // region's corridor span, frozen into this launch like DPR
            // cycles are (1.0 whenever corridor tracking is off).
            let slowdown = self.mgr.corridor_slowdown(region.id);
            let exec_cycles = self.noc_model.charged_exec(base_exec, slowdown);
            // inter-stage pipeline bytes are staged into the region's
            // banks before compute, at contended effective bandwidth
            let stream_in = self.noc_model.stream_in_cycles(
                rt.stream_in_bytes,
                region.footprint().glb_slices,
                slowdown,
            );
            // a rescued launch also waits out the compaction pass; a
            // launch that wakes power-gated domains additionally waits
            // out the wake handshake, charged exactly like DPR cycles
            let woken = region.woken();
            let wake = if woken.0 + woken.1 > 0 { self.wake_cycles } else { 0 };
            let dpr_cycles = dpr_out.cycles
                + wake
                + stream_in
                + self.pending_migration_cycles
                + self.pending_preempt_cycles;
            self.pending_migration_cycles = 0;
            self.pending_preempt_cycles = 0;
            let finish = now + dpr_cycles + exec_cycles;

            self.meter.on_launch(
                region.id,
                &variant.demand.scaled(replicas),
                &region.footprint(),
                &rt.task.0,
                rt.tenant,
                bs_words,
                dpr_out.cache_hit,
                woken,
                self.noc_model.duty_scale(slowdown),
            );
            if self.mgr.noc_enabled() {
                self.noc_stats.on_launch(
                    slowdown,
                    exec_cycles - base_exec,
                    stream_in,
                    hint.is_some(),
                );
            }
            // the running task's configuration state must stay GLB-
            // resident for migration restreams and preemption relaunches
            self.dpr.pin(&bs_id);
            self.running.insert(
                region.id,
                RunningTask {
                    inst: rt.instance,
                    task: rt.task.clone(),
                    ver: opt.ver,
                    tenant: rt.tenant,
                    class: rt.class,
                    deadline: rt.deadline,
                    finish,
                },
            );
            if self.prov_armed {
                alts.push(alt_of(&opt, AltVerdict::Chosen));
                for later in &options[idx + 1..] {
                    alts.push(alt_of(later, AltVerdict::NotTried));
                }
                self.prov_log.push(Decision::new(
                    now,
                    rt.instance.request,
                    DecisionKind::Variant {
                        task: rt.task.0.clone(),
                        chosen: opt.ver.0,
                        replicas,
                        score: opt.eff_throughput,
                        resumed: false,
                        alts: std::mem::take(&mut alts),
                    },
                ));
            }
            return Attempt::Launched(Launch {
                instance: rt.instance,
                task: rt.task.clone(),
                ver: opt.ver,
                region: region.id,
                replicas,
                start: now,
                dpr_cycles,
                exec_cycles,
                finish,
                cache_hit: dpr_out.cache_hit,
                resumed: false,
            });
        }
        if self.prov_armed && !alts.is_empty() {
            self.prov_log.push(Decision::new(
                now,
                rt.instance.request,
                DecisionKind::NoFit { task: rt.task.0.clone(), alts },
            ));
        }
        if blocked.is_empty() {
            Attempt::Impossible
        } else {
            Attempt::Blocked { options: blocked }
        }
    }

    // -------------------------------------------------- defragmentation

    /// Price every step of `plan` against the running tasks' bitstreams.
    fn step_costs(&self, plan: &CompactionPlan) -> Vec<u64> {
        plan.steps
            .iter()
            .map(|step| {
                let stream = self
                    .running
                    .get(&step.region)
                    .and_then(|rt| {
                        self.bitstreams.get(&BitstreamId::new(rt.task.0.clone(), rt.ver.0))
                    })
                    .map(|bs| self.dpr.migration_stream_cycles(bs))
                    .unwrap_or(0);
                self.cost_model.step_cycles(step, stream)
            })
            .collect()
    }

    /// Execute `plan` (priced by `costs`, one entry per step): relocate
    /// regions, extend migrated tasks' finish times, and account stats.
    /// Returns (tasks migrated, total cycles).
    fn commit_plan(&mut self, plan: &CompactionPlan, costs: &[u64], now: u64) -> Result<(u64, u64)> {
        let outcome = execute_plan(&mut self.mgr, plan, costs)?;
        for rec in &outcome.records {
            if let Some(rt) = self.running.get_mut(&rec.region) {
                // the task pauses for its own checkpoint+move window;
                // the remaining work simply shifts right by that much
                rt.finish = rt.finish.max(now) + rec.cycles;
            }
            // joules: restream bits when the array range moved, bank
            // copies when the GLB range moved
            if self.meter.enabled() {
                if let Some(rt) = self.running.get(&rec.region) {
                    let restream_bits = if rec.step.moves_array() {
                        self.bitstreams
                            .get(&BitstreamId::new(rt.task.0.clone(), rt.ver.0))
                            .map(|bs| bs.bits())
                            .unwrap_or(0)
                    } else {
                        0
                    };
                    let glb_bytes =
                        rec.step.moved_glb_slices() as u64 * self.glb_bank_bytes;
                    let pj = self.meter.model().migration_step_pj(restream_bits, glb_bytes);
                    // a relocation into a gated free run wakes those
                    // domains exactly like an allocation would (the
                    // wake latency hides inside the much longer
                    // checkpoint+copy window, so only joules change)
                    let wake_pj = self.meter.model().wake_pj(rec.woken.0, rec.woken.1);
                    let (task, tenant) = (rt.task.0.clone(), rt.tenant);
                    self.meter.on_migration(pj, wake_pj, &task, tenant);
                }
            }
        }
        self.mig_stats.plans_committed += 1;
        self.mig_stats.tasks_migrated += outcome.records.len() as u64;
        self.mig_stats.migration_cycles += outcome.total_cycles;
        if self.obs_armed {
            for rec in &outcome.records {
                if let Some(rt) = self.running.get(&rec.region) {
                    let kind = JournalKind::Migrated {
                        task: rt.task.0.clone(),
                        from: rec.step.from_array.start as u64,
                        to: rec.step.to_array.start as u64,
                        cycles: rec.cycles,
                    };
                    self.obs_log.push((now, kind));
                }
            }
            let defrag = JournalKind::Defrag {
                migrated: outcome.records.len() as u64,
                cycles: outcome.total_cycles,
            };
            self.obs_log.push((now, defrag));
        }
        Ok((outcome.records.len() as u64, outcome.total_cycles))
    }

    /// Ask the planner for a compaction that unblocks one of `rt`'s
    /// blocked variants (tried in policy-preference order); commit the
    /// first viable plan under the defrag policy.  Returns whether a
    /// plan was executed (the caller then retries the launch).
    fn try_defrag_for(
        &mut self,
        rt: &ReadyTask,
        options: &[(VariantId, SliceDemand)],
        now: u64,
    ) -> bool {
        for (ver, demand) in options {
            self.mig_stats.plans_considered += 1;
            let plan = match self.planner.plan(&self.mgr, demand) {
                Some(p) => p,
                None => continue,
            };
            let costs = self.step_costs(&plan);
            let total_cost: u64 = costs.iter().sum();
            // the plan is repaid when the unblocked task's execution
            // time exceeds the cycles the migration pass costs
            let cost_aware = self.planner.policy() == DefragPolicyKind::CostAware;
            let gain = if cost_aware || self.prov_armed {
                self.lib
                    .get(&rt.task)
                    .ok()
                    .and_then(|spec| spec.variant(*ver).map(|v| spec.exec_cycles(v)))
                    .unwrap_or(0)
            } else {
                0
            };
            if cost_aware && total_cost > gain {
                if self.prov_armed {
                    self.prov_log.push(Decision::new(
                        now,
                        rt.instance.request,
                        DecisionKind::Defrag {
                            task: rt.task.0.clone(),
                            ver: ver.0,
                            moves: plan.steps.len() as u32,
                            cost: total_cost,
                            gain,
                            accepted: false,
                        },
                    ));
                }
                continue;
            }
            if self.prov_armed {
                self.prov_log.push(Decision::new(
                    now,
                    rt.instance.request,
                    DecisionKind::Defrag {
                        task: rt.task.0.clone(),
                        ver: ver.0,
                        moves: plan.steps.len() as u32,
                        cost: total_cost,
                        gain,
                        accepted: true,
                    },
                ));
            }
            return match self.commit_plan(&plan, &costs, now) {
                Ok((_, cycles)) => {
                    self.pending_migration_cycles = cycles;
                    true
                }
                Err(_) => {
                    debug_assert!(false, "planner proposed an inexecutable plan");
                    false
                }
            };
        }
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;
    use crate::tasks::{AppId, AppRequest};

    fn sched(policy: RegionPolicyKind) -> Scheduler {
        let cfg = presets::cloud_scenario(policy);
        Scheduler::new(&cfg, TaskLibrary::table1(), DprMode::Fast)
    }

    fn submit(q: &mut RequestQueue, seq: u64, tenant: u32, app: AppId, at: u64) {
        q.submit(AppRequest::new(seq, tenant, app, at));
    }

    #[test]
    fn greedy_picks_fastest_variant_when_idle() {
        let mut s = sched(RegionPolicyKind::FlexibleShape);
        s.preload_all();
        let mut q = RequestQueue::new();
        submit(&mut q, 0, 0, AppId::Harris, 0);
        let launches = s.schedule(&mut q, 0);
        assert_eq!(launches.len(), 1);
        assert_eq!(launches[0].ver, VariantId('c')); // 4 px/cyc, fastest
        assert!(launches[0].cache_hit);
        assert_eq!(s.running_count(), 1);
    }

    #[test]
    fn greedy_falls_back_to_smaller_variant_under_pressure() {
        let mut s = sched(RegionPolicyKind::FlexibleShape);
        s.preload_all();
        let mut q = RequestQueue::new();
        // camera b takes 14 GLB + 6 array; harris c (14 GLB + 7 array)
        // can then never fit (8 array total) — greedy drops to b then a.
        submit(&mut q, 0, 2, AppId::Camera, 0);
        submit(&mut q, 1, 3, AppId::Harris, 0);
        let launches = s.schedule(&mut q, 0);
        assert_eq!(launches.len(), 2);
        assert_eq!(launches[0].task.0, "camera.pipeline");
        assert_eq!(launches[0].ver, VariantId('b'));
        assert_eq!(launches[1].task.0, "harris.corner");
        // 2 array slices remain ⇒ only variant a (2 slices, 4 GLB) fits
        assert_eq!(launches[1].ver, VariantId('a'));
    }

    #[test]
    fn baseline_runs_one_task_at_a_time() {
        let mut s = sched(RegionPolicyKind::Baseline);
        let mut q = RequestQueue::new();
        submit(&mut q, 0, 0, AppId::Camera, 0);
        submit(&mut q, 1, 1, AppId::Harris, 0);
        let launches = s.schedule(&mut q, 0);
        assert_eq!(launches.len(), 1); // second task must wait
        assert_eq!(q.ready_count(), 1);

        // complete the first; next schedule launches the second
        let region = launches[0].region;
        let inst = s.complete(region, launches[0].finish).unwrap();
        q.mark_complete(inst, launches[0].finish).unwrap();
        let launches2 = s.schedule(&mut q, launches[0].finish);
        assert_eq!(launches2.len(), 1);
        assert_eq!(launches2[0].task.0, "harris.corner");
    }

    #[test]
    fn fixed_size_replicates_small_variants() {
        let mut s = sched(RegionPolicyKind::FixedSize);
        s.preload_all();
        let mut q = RequestQueue::new();
        submit(&mut q, 0, 1, AppId::MobileNet, 0);
        let launches = s.schedule(&mut q, 0);
        assert_eq!(launches.len(), 1);
        let l = &launches[0];
        // group 2's variant b (208 = 4×52) needs 5 array slices > unit;
        // greedy instead replicates variant a across 4 units (4×52=208).
        assert_eq!(l.ver, VariantId('a'));
        assert_eq!(l.replicas, 4);
    }

    #[test]
    fn fixed_size_exclusive_fallback_for_oversized() {
        let mut s = sched(RegionPolicyKind::FixedSize);
        s.preload_all();
        let mut q = RequestQueue::new();
        // camera a needs (4 GLB, 4 array) > unit (8, 2) in array dim
        submit(&mut q, 0, 2, AppId::Camera, 0);
        let launches = s.schedule(&mut q, 0);
        assert_eq!(launches.len(), 1);
        // exclusive: the whole machine is taken
        assert_eq!(s.regions().active_count(), 1);
        let (ug, ua) = s.regions().utilization();
        assert_eq!((ug, ua), (1.0, 1.0));
    }

    #[test]
    fn completion_unblocks_chain_successor() {
        let mut s = sched(RegionPolicyKind::FlexibleShape);
        s.preload_all();
        let mut q = RequestQueue::new();
        submit(&mut q, 0, 0, AppId::ResNet18, 0);
        let l1 = s.schedule(&mut q, 0);
        assert_eq!(l1.len(), 1);
        assert_eq!(l1[0].task.0, "resnet18.conv2_x");
        // conv3 not ready until conv2 completes
        assert_eq!(q.ready_count(), 0);
        let inst = s.complete(l1[0].region, l1[0].finish).unwrap();
        q.mark_complete(inst, l1[0].finish).unwrap();
        let l2 = s.schedule(&mut q, l1[0].finish);
        assert_eq!(l2.len(), 1);
        assert_eq!(l2[0].task.0, "resnet18.conv3_x");
    }

    #[test]
    fn fcfs_policy_prefers_smallest_variant() {
        let cfg = {
            let mut c = presets::cloud_scenario(RegionPolicyKind::FlexibleShape);
            c.scheduler.policy = SchedulerPolicyKind::FcfsFirstFit;
            c
        };
        let mut s = Scheduler::new(&cfg, TaskLibrary::table1(), DprMode::Fast);
        let mut q = RequestQueue::new();
        submit(&mut q, 0, 3, AppId::Harris, 0);
        let launches = s.schedule(&mut q, 0);
        assert_eq!(launches[0].ver, VariantId('a'));
    }

    #[test]
    fn complete_unknown_region_errors() {
        let mut s = sched(RegionPolicyKind::FlexibleShape);
        assert!(s.complete(RegionId(42), 0).is_err());
    }

    // ------------------------------------------------- defragmentation

    use crate::config::{DefragPolicyKind, MigrationCostModelKind};

    /// Build a deterministically fragmented machine: four Harris-a
    /// regions (FCFS picks the smallest variant) fill the array; freeing
    /// the 2nd and 4th leaves free array slices {2,3} ∪ {6,7} — four
    /// free slices, largest run two — so camera-a (4 array slices) gets
    /// `NoFit` despite enough total capacity.
    fn fragmented_sched(defrag: DefragPolicyKind) -> (Scheduler, RequestQueue) {
        let mut cfg = presets::cloud_scenario(RegionPolicyKind::FlexibleShape);
        cfg.scheduler.policy = SchedulerPolicyKind::FcfsFirstFit;
        cfg.scheduler.defrag_policy = defrag;
        cfg.scheduler.defrag_threshold = 0.25;
        cfg.scheduler.migration_cost_model = MigrationCostModelKind::Full;
        let mut s = Scheduler::new(&cfg, TaskLibrary::table1(), DprMode::Fast);
        s.preload_all();
        let mut q = RequestQueue::new();
        for seq in 0..4 {
            submit(&mut q, seq, 3, AppId::Harris, 0);
        }
        let launches = s.schedule(&mut q, 0);
        assert_eq!(launches.len(), 4);
        for l in &launches {
            assert_eq!(l.ver, VariantId('a'), "FCFS picks the smallest variant");
        }
        for i in [1usize, 3] {
            let inst = s.complete(launches[i].region, 100).unwrap();
            q.mark_complete(inst, 100).unwrap();
        }
        let (_, fa) = s.regions().fragmentation();
        assert!(fa >= 0.25, "setup must be fragmented: {fa}");
        (s, q)
    }

    #[test]
    fn defrag_off_leaves_blocked_task_waiting() {
        let (mut s, mut q) = fragmented_sched(DefragPolicyKind::Off);
        submit(&mut q, 10, 2, AppId::Camera, 100);
        let launches = s.schedule(&mut q, 100);
        assert!(launches.is_empty(), "camera cannot fit in the scattered holes");
        assert_eq!(q.ready_count(), 1);
        assert_eq!(s.migration_stats().tasks_migrated, 0);
    }

    #[test]
    fn greedy_defrag_rescues_a_blocked_launch() {
        let (mut s, mut q) = fragmented_sched(DefragPolicyKind::Greedy);
        let migrated_region = {
            // the surviving region at array [4..6) is the one that moves
            let mut regions: Vec<_> =
                s.regions().active().map(|r| (r.array[0].start, r.id)).collect();
            regions.sort();
            regions[1].1
        };
        let finish_before = s.finish_of(migrated_region).unwrap();

        submit(&mut q, 10, 2, AppId::Camera, 100);
        let launches = s.schedule(&mut q, 100);
        assert_eq!(launches.len(), 1, "compaction must rescue the launch");
        let l = &launches[0];
        assert_eq!(l.ver, VariantId('a'));

        let stats = s.migration_stats();
        assert!(stats.nofit_events >= 1);
        assert_eq!(stats.plans_committed, 1);
        assert_eq!(stats.tasks_migrated, 1);
        assert_eq!(stats.rescued_launches, 1);
        // full cost model: checkpoint 64 + restream 3344 + GLB copy 16384
        assert_eq!(stats.migration_cycles, 64 + 3344 + 16_384);
        // the rescued launch waits out the compaction pass...
        assert!(l.dpr_cycles >= stats.migration_cycles, "{}", l.dpr_cycles);
        // ...and the migrated task's completion moved out by its pause
        let finish_after = s.finish_of(migrated_region).unwrap();
        assert_eq!(finish_after, finish_before + stats.migration_cycles);
        // the maps are compact again
        assert_eq!(s.regions().fragmentation().1, 0.0);
    }

    #[test]
    fn cost_aware_defrag_commits_when_repaid() {
        // camera-a runs 691,200 cycles; the pass costs ~20k — repaid.
        let (mut s, mut q) = fragmented_sched(DefragPolicyKind::CostAware);
        submit(&mut q, 10, 2, AppId::Camera, 100);
        let launches = s.schedule(&mut q, 100);
        assert_eq!(launches.len(), 1);
        assert_eq!(s.migration_stats().rescued_launches, 1);
    }

    #[test]
    fn cost_aware_defrag_refuses_unrepaid_plans() {
        // Blow the GLB banks up to 1 GiB so the bank-to-bank copy alone
        // (134M cycles) dwarfs camera-a's 691k execution cycles.
        let mut cfg = presets::cloud_scenario(RegionPolicyKind::FlexibleShape);
        cfg.arch.glb_bank_kib = 1 << 20;
        cfg.scheduler.policy = SchedulerPolicyKind::FcfsFirstFit;
        cfg.scheduler.defrag_policy = DefragPolicyKind::CostAware;
        cfg.scheduler.defrag_threshold = 0.25;
        let mut s = Scheduler::new(&cfg, TaskLibrary::table1(), DprMode::Fast);
        s.preload_all();
        let mut q = RequestQueue::new();
        for seq in 0..4 {
            submit(&mut q, seq, 3, AppId::Harris, 0);
        }
        let launches = s.schedule(&mut q, 0);
        assert_eq!(launches.len(), 4);
        for i in [1usize, 3] {
            let inst = s.complete(launches[i].region, 100).unwrap();
            q.mark_complete(inst, 100).unwrap();
        }
        submit(&mut q, 10, 2, AppId::Camera, 100);
        let rescued = s.schedule(&mut q, 100);
        assert!(rescued.is_empty(), "unrepaid plan must be refused");
        let stats = s.migration_stats();
        assert!(stats.plans_considered >= 1);
        assert_eq!(stats.plans_committed, 0);
        assert_eq!(stats.tasks_migrated, 0);
    }

    #[test]
    fn defrag_now_compacts_without_a_blocked_task() {
        let (mut s, _q) = fragmented_sched(DefragPolicyKind::Greedy);
        let report = s.defrag_now(100);
        assert_eq!(report.migrated, 1);
        assert!(report.cycles > 0);
        assert!(report.frag_before.1 > 0.0);
        assert_eq!(report.frag_after, (0.0, 0.0));
        // idempotent: a second pass has nothing to do
        let again = s.defrag_now(200);
        assert_eq!(again.migrated, 0);
        assert_eq!(again.cycles, 0);
    }

    // ------------------------------------------------- energy + governor

    fn energy_sched(cap_watts: f64) -> Scheduler {
        let mut cfg = presets::cloud_scenario(RegionPolicyKind::FlexibleShape);
        cfg.energy.enabled = true;
        cfg.energy.power_cap_watts = cap_watts;
        let mut s = Scheduler::new(&cfg, TaskLibrary::table1(), DprMode::Fast);
        s.preload_all();
        s
    }

    #[test]
    fn launch_on_gated_fabric_charges_wake_cycles() {
        let mut s = energy_sched(0.0);
        let mut q = RequestQueue::new();
        submit(&mut q, 0, 3, AppId::Harris, 0);
        let launches = s.schedule(&mut q, 0);
        assert_eq!(launches.len(), 1);
        // identical run with energy off: the only dpr_cycles difference
        // is the configured wake latency (default 96)
        let mut off = sched(RegionPolicyKind::FlexibleShape);
        off.preload_all();
        let mut q2 = RequestQueue::new();
        submit(&mut q2, 0, 3, AppId::Harris, 0);
        let baseline = off.schedule(&mut q2, 0);
        assert_eq!(
            launches[0].dpr_cycles,
            baseline[0].dpr_cycles + 96,
            "wake latency is charged like DPR cycles"
        );
        assert_eq!(launches[0].ver, baseline[0].ver, "variant choice is unchanged");
    }

    #[test]
    fn energy_report_accounts_a_run_and_conserves() {
        let mut s = energy_sched(0.0);
        let mut q = RequestQueue::new();
        submit(&mut q, 0, 3, AppId::Harris, 0);
        let l = s.schedule(&mut q, 0)[0].clone();
        let inst = s.complete(l.region, l.finish).unwrap();
        q.mark_complete(inst, l.finish).unwrap();
        let r = s.energy_report(l.finish + 1000).expect("enabled");
        assert!(r.total_j > 0.0);
        assert!(r.pe_j > 0.0 && r.mem_j > 0.0 && r.glb_j > 0.0 && r.dpr_j > 0.0);
        assert!(r.wake_j > 0.0, "fresh gated fabric must charge a wake");
        assert!((r.component_sum_j() - r.total_j).abs() <= 1e-9 * r.total_j);
        assert!(r.per_task.contains_key("harris.corner"));
        assert!(r.per_tenant[3] > 0.0);
        // disabled scheduler reports nothing
        let mut off = sched(RegionPolicyKind::FlexibleShape);
        assert!(off.energy_report(1000).is_none());
    }

    #[test]
    fn governor_degrades_to_smaller_variants_under_a_tight_cap() {
        // 1.5 W: harris c (~2.2 W active) never passes the admit check
        // once anything runs, but the drained-fabric bypass still
        // launches the *first* task, and later tasks degrade or wait.
        let mut s = energy_sched(1.5);
        let mut q = RequestQueue::new();
        submit(&mut q, 0, 3, AppId::Harris, 0);
        submit(&mut q, 1, 3, AppId::Harris, 0);
        let launches = s.schedule(&mut q, 0);
        assert!(
            !launches.is_empty(),
            "drained fabric must always make progress under any cap"
        );
        let first = &launches[0];
        // the second harris (if launched at all) got a smaller variant
        // than the uncapped fastest choice, or waited
        if launches.len() > 1 {
            assert!(launches[1].ver < VariantId('c'), "{:?}", launches[1].ver);
        }
        assert!(s.energy().throttled() > 0, "the cap must have refused options");
        assert_eq!(first.ver, VariantId('c'), "bypass launch is unthrottled");
    }

    #[test]
    fn uncapped_governor_never_throttles() {
        let mut s = energy_sched(0.0);
        let mut q = RequestQueue::new();
        for seq in 0..6 {
            submit(&mut q, seq, (seq % 4) as u32, AppId::Harris, 0);
        }
        let _ = s.schedule(&mut q, 0);
        assert_eq!(s.energy().throttled(), 0);
    }

    #[test]
    fn energy_aware_policy_minimizes_edp_ordering() {
        let mut cfg = presets::cloud_scenario(RegionPolicyKind::FlexibleShape);
        cfg.energy.enabled = true;
        cfg.scheduler.policy = SchedulerPolicyKind::EnergyAware;
        let mut s = Scheduler::new(&cfg, TaskLibrary::table1(), DprMode::Fast);
        s.preload_all();
        let mut q = RequestQueue::new();
        submit(&mut q, 0, 3, AppId::Harris, 0);
        let launches = s.schedule(&mut q, 0);
        assert_eq!(launches.len(), 1);
        // Table 1 harris EDP ∝ P·t²: a → 2·w², b → 4·(w/2)² = w²,
        // c → 7·(w/4)² ≈ 0.44·w² (array-dominated) — c minimizes EDP.
        assert_eq!(launches[0].ver, VariantId('c'));
        // under pressure the ordering still walks the EDP ranking: with
        // 1 array slice left nothing fits and the task waits
        submit(&mut q, 1, 2, AppId::Camera, 0);
        let second = s.schedule(&mut q, 0);
        // camera: a → 4·w², b → 6·(w/4)² = 0.375·w²; only 1 slice free
        // now, so neither fits (camera-a needs 4) and it blocks
        assert!(second.is_empty());
        assert_eq!(q.ready_count(), 1);
    }

    // ------------------------------------------------- qos + preemption

    use crate::config::{QosClass, QosPolicyKind};

    fn qos_sched(preemptive: bool) -> Scheduler {
        let mut cfg = presets::cloud_scenario(RegionPolicyKind::FlexibleShape);
        cfg.qos.enabled = true;
        cfg.qos.policy = QosPolicyKind::Edf;
        cfg.qos.preemption = preemptive;
        let mut s = Scheduler::new(&cfg, TaskLibrary::table1(), DprMode::Fast);
        s.preload_all();
        s
    }

    #[test]
    fn critical_preempts_best_effort_and_victim_resumes_exactly_once() {
        let mut s = qos_sched(true);
        let mut q = RequestQueue::new();
        // BestEffort harris grabs the fastest variant (7 array slices)
        submit(&mut q, 0, 3, AppId::Harris, 0);
        let l1 = s.schedule(&mut q, 0);
        assert_eq!(l1.len(), 1);
        assert_eq!(l1[0].ver, VariantId('c'));
        let victim_region = l1[0].region;
        let victim_finish = l1[0].finish;

        // a Critical camera arrives: no variant fits → harris is evicted
        q.submit(
            AppRequest::new(1, 2, AppId::Camera, 10)
                .with_qos(QosClass::Critical, Some(5_000_000)),
        );
        let l2 = s.schedule(&mut q, 10);
        assert_eq!(l2.len(), 1, "preemption must rescue the critical launch");
        assert_eq!(l2[0].task.0, "camera.pipeline");
        let stats = s.qos_stats();
        assert_eq!(stats.preemptions, 1);
        assert_eq!(stats.victims_evicted, 1);
        assert_eq!(stats.rescued_by_preemption, 1);
        assert_eq!(s.checkpointed_count(), 1);
        // the rescued launch waits out the checkpoint (full model)
        let ckpt = s.cost_model.checkpoint_cycles();
        assert_eq!(ckpt, 64 + 16_384);
        assert!(l2[0].dpr_cycles >= ckpt, "{}", l2[0].dpr_cycles);
        // the eviction record is strictly class-ascending
        let log = s.take_preemptions();
        assert_eq!(log.len(), 1);
        assert_eq!(log[0].victim_class, QosClass::BestEffort);
        assert_eq!(log[0].preemptor_class, QosClass::Critical);
        assert_eq!(log[0].victim_region, victim_region);
        assert_eq!(log[0].remaining_cycles, victim_finish - 10);
        // the victim's stale completion event is invalidated exactly once
        assert!(s.take_cancelled(victim_region));
        assert!(!s.take_cancelled(victim_region));

        // camera completes → harris resumes with its saved variant and
        // its remaining cycles
        let inst = s.complete(l2[0].region, l2[0].finish).unwrap();
        q.mark_complete(inst, l2[0].finish).unwrap();
        let l3 = s.schedule(&mut q, l2[0].finish);
        assert_eq!(l3.len(), 1, "checkpointed victim must resume");
        assert_eq!(l3[0].task.0, "harris.corner");
        assert_eq!(l3[0].ver, VariantId('c'), "resume keeps the checkpointed variant");
        assert_eq!(l3[0].exec_cycles, victim_finish - 10);
        assert!(l3[0].cache_hit, "pinned bitstream must still be resident");
        // resume pays the GLB state copy-in on top of the restream
        assert!(l3[0].dpr_cycles >= s.cost_model.resume_extra_cycles());
        assert_eq!(s.qos_stats().victims_resumed, 1);
        assert_eq!(s.checkpointed_count(), 0);

        // drain: completion happens exactly once, resources conserved
        let inst = s.complete(l3[0].region, l3[0].finish).unwrap();
        let done = q.mark_complete(inst, l3[0].finish).unwrap();
        assert!(done.is_some(), "victim's request completes exactly once");
        assert_eq!(s.running_count(), 0);
        assert_eq!(s.regions().glb_map().busy_count(), 0);
        assert_eq!(s.regions().array_map().busy_count(), 0);
        assert_eq!(q.open_requests(), 0);
    }

    #[test]
    fn lower_classes_never_preempt_higher_or_equal() {
        let mut s = qos_sched(true);
        let mut q = RequestQueue::new();
        // Critical harris-c holds 7 of 8 array slices
        q.submit(AppRequest::new(0, 3, AppId::Harris, 0).with_qos(QosClass::Critical, None));
        assert_eq!(s.schedule(&mut q, 0).len(), 1);
        // BestEffort, Interactive and equal-class Critical camera all
        // block without evicting anyone
        for (seq, class) in [
            (1, QosClass::BestEffort),
            (2, QosClass::Interactive),
            (3, QosClass::Critical),
        ] {
            q.submit(AppRequest::new(seq, 2, AppId::Camera, 10).with_qos(class, Some(100)));
        }
        let launches = s.schedule(&mut q, 10);
        assert!(launches.is_empty(), "nothing may evict the critical task");
        assert_eq!(s.qos_stats().victims_evicted, 0);
        assert_eq!(q.ready_count(), 3);
        assert_eq!(s.lower_class_runway(QosClass::Critical, 10), 0, "no lower-class runway");
    }

    #[test]
    fn preemption_disabled_blocks_instead_of_evicting() {
        let mut s = qos_sched(false);
        let mut q = RequestQueue::new();
        submit(&mut q, 0, 3, AppId::Harris, 0); // BestEffort
        assert_eq!(s.schedule(&mut q, 0).len(), 1);
        q.submit(AppRequest::new(1, 2, AppId::Camera, 10).with_qos(QosClass::Critical, None));
        assert!(s.schedule(&mut q, 10).is_empty());
        assert_eq!(s.qos_stats(), crate::qos::QosStats::default());
        assert_eq!(s.checkpointed_count(), 0);
    }

    #[test]
    fn fifo_policy_never_preempts_even_with_the_knob_set() {
        let mut cfg = presets::cloud_scenario(RegionPolicyKind::FlexibleShape);
        cfg.qos.enabled = true;
        cfg.qos.policy = QosPolicyKind::Fifo;
        cfg.qos.preemption = true; // the default — fifo must override it
        let mut s = Scheduler::new(&cfg, TaskLibrary::table1(), DprMode::Fast);
        s.preload_all();
        let mut q = RequestQueue::new();
        submit(&mut q, 0, 3, AppId::Harris, 0); // BestEffort fills the array
        assert_eq!(s.schedule(&mut q, 0).len(), 1);
        q.submit(AppRequest::new(1, 2, AppId::Camera, 10).with_qos(QosClass::Critical, None));
        assert!(s.schedule(&mut q, 10).is_empty(), "fifo is scheduling-neutral");
        assert_eq!(s.qos_stats().victims_evicted, 0);
    }

    #[test]
    fn edf_orders_criticals_by_deadline() {
        let mut s = qos_sched(true);
        let mut q = RequestQueue::new();
        // two critical harris requests; only one fits at the fastest
        // variant — the earlier deadline must win the head slot even
        // though it arrived later
        q.submit(AppRequest::new(0, 3, AppId::Harris, 0).with_qos(QosClass::Critical, Some(9_000_000)));
        q.submit(AppRequest::new(1, 3, AppId::Harris, 5).with_qos(QosClass::Critical, Some(1_000_000)));
        let launches = s.schedule(&mut q, 10);
        assert!(!launches.is_empty());
        assert_eq!(launches[0].instance.request, 1, "EDF head slot");
    }

    #[test]
    fn best_effort_runway_feeds_class_aware_placement() {
        let mut s = qos_sched(true);
        let mut q = RequestQueue::new();
        submit(&mut q, 0, 3, AppId::Harris, 0); // BestEffort
        let l = s.schedule(&mut q, 0);
        let runway = s.lower_class_runway(QosClass::Critical, 0);
        assert_eq!(runway, l[0].finish);
        assert_eq!(s.lower_class_runway(QosClass::BestEffort, 0), 0);
        // past the finish the runway saturates to zero
        assert_eq!(s.lower_class_runway(QosClass::Critical, l[0].finish + 1), 0);
    }

    // ------------------------------------------- frontier ordering + sorts

    #[test]
    fn fair_share_derives_rotation_modulus_from_tenant_span() {
        let mut cfg = presets::cloud_scenario(RegionPolicyKind::FlexibleShape);
        cfg.scheduler.policy = SchedulerPolicyKind::FairShare;
        let mut s = Scheduler::new(&cfg, TaskLibrary::table1(), DprMode::Fast);
        let mut q = RequestQueue::new();
        for t in 0..5u32 {
            submit(&mut q, t as u64, t, AppId::Harris, 0);
        }
        assert_eq!(q.tenant_span(), 5);
        let order = |s: &Scheduler, q: &RequestQueue| -> Vec<u32> {
            s.order_ready(q.ready_tasks(), q.tenant_span(), 0)
                .iter()
                .map(|r| r.tenant)
                .collect()
        };
        // cursor 0: plain tenant order
        assert_eq!(order(&s, &q), vec![0, 1, 2, 3, 4]);
        // Regression: after four rotation steps tenant 4 must win the
        // head slot.  The old hard-coded `% 4` modulus aliased tenant 4
        // onto tenant 0's slot, so it could never lead the frontier.
        s.rr_cursor = 4;
        assert_eq!(order(&s, &q), vec![4, 0, 1, 2, 3]);
        // the rotation is periodic in the derived span, not in 4
        s.rr_cursor = 9;
        assert_eq!(order(&s, &q), vec![4, 0, 1, 2, 3]);
    }

    #[test]
    fn fair_share_five_tenants_all_reach_the_head_slot() {
        // End-to-end slice of the same regression: five tenants keep the
        // frontier saturated; every tenant must get launches, because
        // every tenant periodically holds the head slot.
        let mut cfg = presets::cloud_scenario(RegionPolicyKind::FlexibleShape);
        cfg.scheduler.policy = SchedulerPolicyKind::FairShare;
        let mut s = Scheduler::new(&cfg, TaskLibrary::table1(), DprMode::Fast);
        s.preload_all();
        let mut q = RequestQueue::new();
        let mut seq = 0u64;
        for round in 0..5u64 {
            for t in 0..5u32 {
                submit(&mut q, seq, t, AppId::Harris, round * 10);
                seq += 1;
            }
        }
        let mut launched_tenants = std::collections::BTreeSet::new();
        let mut now = 0u64;
        let mut pending: Vec<Launch> = Vec::new();
        for _ in 0..200 {
            for l in s.schedule(&mut q, now) {
                pending.push(l);
            }
            if q.ready_count() == 0 && pending.is_empty() {
                break;
            }
            pending.sort_by_key(|l| l.finish);
            if let Some(l) = pending.first().cloned() {
                pending.remove(0);
                now = l.finish;
                let inst = s.complete(l.region, now).unwrap();
                let rt_tenant = inst.request % 5;
                launched_tenants.insert(rt_tenant as u32);
                q.mark_complete(inst, now).unwrap();
            }
        }
        assert_eq!(
            launched_tenants.len(),
            5,
            "all five tenants must be served: {launched_tenants:?}"
        );
    }

    #[test]
    fn degenerate_variant_throughputs_never_panic_the_option_sort() {
        // Regression: `partial_cmp(..).unwrap()` panicked on NaN
        // throughputs; `total_cmp` keeps the sort total.  A zero-work /
        // zero-throughput variant yields NaN and ±inf effective
        // throughputs in derived quantities — construction (which
        // precomputes every task's option order) must survive all of it.
        use crate::tasks::{TaskSpec, VariantSpec, WorkUnit};
        let mut lib = TaskLibrary::table1();
        lib.insert(TaskSpec {
            id: TaskId::new("degenerate.zero"),
            name: "degenerate zero-cycle task".into(),
            work: 0,
            unit: WorkUnit::Macs,
            variants: vec![
                VariantSpec::new('a', f64::NAN, 2, 4),
                VariantSpec::new('b', 1.0, 2, 4),
                VariantSpec::new('c', 0.0, 2, 4),
            ],
        });
        for policy in [
            SchedulerPolicyKind::GreedyThroughput,
            SchedulerPolicyKind::FcfsFirstFit,
            SchedulerPolicyKind::FairShare,
            SchedulerPolicyKind::ShortestJobFirst,
        ] {
            let mut cfg = presets::cloud_scenario(RegionPolicyKind::FlexibleShape);
            cfg.scheduler.policy = policy;
            let s = Scheduler::new(&cfg, lib.clone(), DprMode::Fast);
            let opts = &s.options[&TaskId::new("degenerate.zero")];
            assert_eq!(opts.len(), 3, "{policy:?}");
            // total_cmp is a total order: NaN sorts above +inf, which
            // sorts above finite values — descending policies lead with
            // the NaN variant, ascending (FCFS) ends with it.
            match policy {
                SchedulerPolicyKind::FcfsFirstFit => {
                    assert!(opts[2].eff_throughput.is_nan(), "{policy:?}")
                }
                _ => assert!(opts[0].eff_throughput.is_nan(), "{policy:?}"),
            }
        }
        // the ordinary Table 1 tasks are untouched by the degenerate spec
        let cfg = presets::cloud_scenario(RegionPolicyKind::FlexibleShape);
        let mut s = Scheduler::new(&cfg, lib, DprMode::Fast);
        s.preload_all();
        let mut q = RequestQueue::new();
        submit(&mut q, 0, 3, AppId::Harris, 0);
        assert_eq!(s.schedule(&mut q, 0).len(), 1);
    }

    #[test]
    fn precomputed_options_match_a_fresh_enumeration() {
        // The cache is filled at construction; every task's cached order
        // must be exactly what `options_for` would compute now.
        for policy in RegionPolicyKind::ALL {
            let s = sched(policy);
            for t in s.lib.iter() {
                let fresh = s.options_for(&t.id);
                let cached = &s.options[&t.id];
                assert_eq!(cached.len(), fresh.len(), "{policy:?} {}", t.id);
                for (c, f) in cached.iter().zip(fresh.iter()) {
                    assert_eq!(c.ver, f.ver, "{policy:?} {}", t.id);
                    assert_eq!(c.replicate, f.replicate);
                    assert_eq!(c.exclusive, f.exclusive);
                    assert!(c.eff_throughput.total_cmp(&f.eff_throughput).is_eq());
                }
            }
        }
    }

    #[test]
    fn drain_completion_resolves_all_three_outcomes() {
        let mut s = sched(RegionPolicyKind::FlexibleShape);
        s.preload_all();
        let mut q = RequestQueue::new();
        submit(&mut q, 0, 3, AppId::Harris, 0);
        let l = s.schedule(&mut q, 0)[0].clone();
        // early event: the task has not finished yet → Stale(finish)
        assert_eq!(
            s.drain_completion(l.region, l.finish - 1).unwrap(),
            CompletionOutcome::Stale(l.finish)
        );
        // on-time event → Done(instance)
        match s.drain_completion(l.region, l.finish).unwrap() {
            CompletionOutcome::Done(inst) => {
                assert_eq!(inst, l.instance);
                q.mark_complete(inst, l.finish).unwrap();
            }
            other => panic!("expected Done, got {other:?}"),
        }
        // unknown region errors exactly like `complete`
        assert!(s.drain_completion(RegionId(99), 0).is_err());
    }

    #[test]
    fn drain_completion_consumes_cancellation_markers() {
        let mut s = qos_sched(true);
        let mut q = RequestQueue::new();
        submit(&mut q, 0, 3, AppId::Harris, 0);
        let l1 = s.schedule(&mut q, 0);
        let victim_region = l1[0].region;
        q.submit(
            AppRequest::new(1, 2, AppId::Camera, 10)
                .with_qos(QosClass::Critical, Some(5_000_000)),
        );
        assert_eq!(s.schedule(&mut q, 10).len(), 1);
        // the victim's stale completion event resolves Cancelled once…
        assert_eq!(
            s.drain_completion(victim_region, l1[0].finish).unwrap(),
            CompletionOutcome::Cancelled
        );
        // …and the marker is consumed (the region now belongs to the
        // preemptor, so a second drain is a Stale or Done for *it*, or
        // an error if the id was never reused — never Cancelled again)
        assert_ne!(
            s.drain_completion(victim_region, 0).ok(),
            Some(CompletionOutcome::Cancelled)
        );
    }

    #[test]
    fn exec_cycles_match_table1_math() {
        let mut s = sched(RegionPolicyKind::FlexibleShape);
        s.preload_all();
        let mut q = RequestQueue::new();
        submit(&mut q, 0, 2, AppId::Camera, 0);
        let l = &s.schedule(&mut q, 0)[0];
        // camera b: 2,073,600 px / 12 px-per-cycle = 172,800 cycles
        assert_eq!(l.exec_cycles, 172_800);
        assert_eq!(l.finish, l.start + l.dpr_cycles + l.exec_cycles);
    }

    // --------------------------------------------------------------- noc

    fn pipeline_sched(noc: bool) -> Scheduler {
        let mut cfg = presets::cloud_scenario(RegionPolicyKind::FlexibleShape);
        cfg.noc.enabled = noc;
        Scheduler::new(&cfg, TaskLibrary::table1_pipeline(), DprMode::Fast)
    }

    /// Drive one Pipeline request through its first two stages (camera →
    /// demosaic) and return both launches.
    fn run_two_pipeline_stages(s: &mut Scheduler) -> (Launch, Launch) {
        s.preload_all();
        let mut q = RequestQueue::new();
        submit(&mut q, 0, 0, AppId::Pipeline, 0);
        let l1 = s.schedule(&mut q, 0)[0].clone();
        let inst = s.complete(l1.region, l1.finish).unwrap();
        q.mark_complete(inst, l1.finish).unwrap();
        let l2 = s.schedule(&mut q, l1.finish)[0].clone();
        (l1, l2)
    }

    #[test]
    fn pipeline_stage_pays_stream_in_only_when_noc_is_on() {
        let mut off = pipeline_sched(false);
        let (off1, off2) = run_two_pipeline_stages(&mut off);
        assert!(off.noc_report().is_none(), "disabled NoC reports nothing");

        let mut on = pipeline_sched(true);
        let (on1, on2) = run_two_pipeline_stages(&mut on);
        // the graph source streams nothing in; on an otherwise-idle
        // fabric comm-aware placement agrees with first-fit, so stage 1
        // is cycle-identical
        assert_eq!(on1.region, off1.region);
        assert_eq!(on1.dpr_cycles, off1.dpr_cycles);
        assert_eq!(on1.exec_cycles, off1.exec_cycles);
        // stage 2 (demosaic b: 12 GLB banks) stages a 1080p 16-bit frame
        // before compute: 4,147,200 B over 12 banks × 8 B/cycle =
        // 43,200 cycles at slowdown 1.0
        assert_eq!(on2.exec_cycles, off2.exec_cycles, "uncontended: no exec stretch");
        assert_eq!(on2.dpr_cycles, off2.dpr_cycles + 43_200);

        let r = on.noc_report().expect("enabled NoC reports");
        assert_eq!(r.streams_placed, 2);
        assert_eq!(r.stream_in_cycles, 43_200);
        assert_eq!(r.contended_launches, 0, "one region at a time never contends");
        assert_eq!(r.mean_slowdown, 1.0);
        assert_eq!(r.affinity_hits, 1, "stage 2 placed with stage 1's position hint");
        assert_eq!(r.corridors, 8);
        assert_eq!(r.capacity, 20);
    }

    #[test]
    fn noc_disabled_keeps_fig3a_launches_untouched() {
        // knobs without the master switch change nothing, even with the
        // pipeline-capable library loaded
        let mut plain = sched(RegionPolicyKind::FlexibleShape);
        let mut knobs = {
            let mut cfg = presets::cloud_scenario(RegionPolicyKind::FlexibleShape);
            cfg.noc.comm_fraction = 0.9;
            cfg.noc.placement = crate::config::NocPlacementKind::Oblivious;
            cfg.noc.stream_affinity = false;
            Scheduler::new(&cfg, TaskLibrary::table1(), DprMode::Fast)
        };
        for s in [&mut plain, &mut knobs] {
            s.preload_all();
        }
        let mut qa = RequestQueue::new();
        let mut qb = RequestQueue::new();
        for (seq, app) in
            [AppId::Camera, AppId::Harris, AppId::ResNet18, AppId::MobileNet].iter().enumerate()
        {
            submit(&mut qa, seq as u64, seq as u32, *app, 0);
            submit(&mut qb, seq as u64, seq as u32, *app, 0);
        }
        let la = plain.schedule(&mut qa, 0);
        let lb = knobs.schedule(&mut qb, 0);
        assert_eq!(la.len(), lb.len());
        for (a, b) in la.iter().zip(lb.iter()) {
            assert_eq!(a.region, b.region);
            assert_eq!(a.ver, b.ver);
            assert_eq!(a.dpr_cycles, b.dpr_cycles);
            assert_eq!(a.exec_cycles, b.exec_cycles);
            assert_eq!(a.finish, b.finish);
        }
        assert!(knobs.noc_report().is_none());
    }

    // ------------------------------------------------ decision provenance

    #[test]
    fn provenance_disarmed_records_nothing() {
        let mut s = sched(RegionPolicyKind::FlexibleShape);
        s.preload_all();
        let mut q = RequestQueue::new();
        submit(&mut q, 0, 2, AppId::Camera, 0);
        submit(&mut q, 1, 3, AppId::Harris, 0);
        s.schedule(&mut q, 0);
        assert!(s.take_decisions().is_empty());
    }

    #[test]
    fn provenance_records_variant_choice_with_rejected_alternatives() {
        let mut s = sched(RegionPolicyKind::FlexibleShape);
        s.set_provenance(true);
        s.preload_all();
        let mut q = RequestQueue::new();
        // camera takes 14 GLB + 6 array; harris then falls back to a
        submit(&mut q, 0, 2, AppId::Camera, 0);
        submit(&mut q, 1, 3, AppId::Harris, 0);
        let launches = s.schedule(&mut q, 0);
        assert_eq!(launches.len(), 2);
        let ds = s.take_decisions();
        let harris: Vec<_> = ds
            .iter()
            .filter(|d| d.req == 1 && matches!(d.kind, DecisionKind::Variant { .. }))
            .collect();
        assert_eq!(harris.len(), 1, "one variant decision per launch");
        match &harris[0].kind {
            DecisionKind::Variant { chosen, alts, resumed, .. } => {
                assert_eq!(*chosen, 'a');
                assert!(!resumed);
                assert_eq!(
                    alts.iter().filter(|a| a.verdict == AltVerdict::Chosen).count(),
                    1
                );
                assert!(
                    alts.iter().any(|a| a.verdict != AltVerdict::Chosen),
                    "rejected alternatives must be recorded: {alts:?}"
                );
            }
            other => panic!("unexpected kind {other:?}"),
        }
        assert!(s.take_decisions().is_empty(), "drain empties the log");
    }

    #[test]
    fn provenance_records_preemption_ranking_and_resume() {
        let mut s = qos_sched(true);
        s.set_provenance(true);
        let mut q = RequestQueue::new();
        submit(&mut q, 0, 3, AppId::Harris, 0);
        s.schedule(&mut q, 0);
        s.take_decisions();
        q.submit(
            AppRequest::new(1, 2, AppId::Camera, 10)
                .with_qos(QosClass::Critical, Some(5_000_000)),
        );
        let l2 = s.schedule(&mut q, 10);
        assert_eq!(l2.len(), 1);
        let ds = s.take_decisions();
        let preempt = ds
            .iter()
            .find(|d| matches!(d.kind, DecisionKind::Preempt { .. }))
            .expect("eviction must leave a preempt decision");
        assert_eq!(preempt.req, 1);
        match &preempt.kind {
            DecisionKind::Preempt { candidates, evicted, .. } => {
                assert_eq!(*evicted, 1);
                assert_eq!(candidates.len(), 1);
                assert!(candidates[0].evicted);
                assert_eq!(candidates[0].class, "best-effort");
            }
            other => panic!("unexpected kind {other:?}"),
        }
        assert!(
            ds.iter().any(|d| matches!(d.kind, DecisionKind::NoFit { .. })),
            "the blocked first attempt must leave a nofit root cause"
        );

        // complete the critical task → the victim resumes, provenanced
        let inst = s.complete(l2[0].region, l2[0].finish).unwrap();
        q.mark_complete(inst, l2[0].finish).unwrap();
        let l3 = s.schedule(&mut q, l2[0].finish);
        assert_eq!(l3.len(), 1);
        let ds = s.take_decisions();
        assert!(
            ds.iter().any(|d| matches!(
                d.kind,
                DecisionKind::Variant { resumed: true, .. }
            )),
            "resume must record a resumed variant decision: {ds:?}"
        );
    }

    #[test]
    fn provenance_records_defrag_accept_and_cost_reject() {
        let (mut s, mut q) = fragmented_sched(DefragPolicyKind::Greedy);
        s.set_provenance(true);
        submit(&mut q, 10, 2, AppId::Camera, 100);
        assert_eq!(s.schedule(&mut q, 100).len(), 1);
        let ds = s.take_decisions();
        let accepted = ds
            .iter()
            .find(|d| matches!(d.kind, DecisionKind::Defrag { accepted: true, .. }))
            .expect("committed plan must be provenanced");
        match &accepted.kind {
            DecisionKind::Defrag { moves, cost, .. } => {
                assert_eq!(*moves, 1);
                assert_eq!(*cost, 64 + 3344 + 16_384);
            }
            other => panic!("unexpected kind {other:?}"),
        }

        // cost-aware reject: blow up the GLB bank size so the copy is
        // never repaid (mirrors cost_aware_defrag_refuses_unrepaid_plans)
        let mut cfg = presets::cloud_scenario(RegionPolicyKind::FlexibleShape);
        cfg.arch.glb_bank_kib = 1 << 20;
        cfg.scheduler.policy = SchedulerPolicyKind::FcfsFirstFit;
        cfg.scheduler.defrag_policy = DefragPolicyKind::CostAware;
        cfg.scheduler.defrag_threshold = 0.25;
        let mut s = Scheduler::new(&cfg, TaskLibrary::table1(), DprMode::Fast);
        s.set_provenance(true);
        s.preload_all();
        let mut q = RequestQueue::new();
        for seq in 0..4 {
            submit(&mut q, seq, 3, AppId::Harris, 0);
        }
        let launches = s.schedule(&mut q, 0);
        assert_eq!(launches.len(), 4);
        for i in [1usize, 3] {
            let inst = s.complete(launches[i].region, 100).unwrap();
            q.mark_complete(inst, 100).unwrap();
        }
        s.take_decisions();
        submit(&mut q, 10, 2, AppId::Camera, 100);
        assert!(s.schedule(&mut q, 100).is_empty());
        let ds = s.take_decisions();
        let rejected = ds
            .iter()
            .find(|d| matches!(d.kind, DecisionKind::Defrag { accepted: false, .. }))
            .expect("cost-aware refusal must be provenanced");
        match &rejected.kind {
            DecisionKind::Defrag { cost, gain, .. } => {
                assert!(cost > gain, "refusal implies cost {cost} > gain {gain}");
            }
            other => panic!("unexpected kind {other:?}"),
        }
    }
}
