//! The scheduler core: policy-driven variant selection + region binding.

use std::collections::BTreeMap;

use crate::abstraction::SliceRange;
use crate::compiler::generate_bitstream;
use crate::config::{Config, RegionPolicyKind, SchedulerPolicyKind};
use crate::dpr::{Bitstream, BitstreamId, DprEngine, DprMode};
use crate::error::{Error, Result};
use crate::regions::{AllocOutcome, ExecutionRegion, RegionId, RegionManager};
use crate::tasks::{TaskId, TaskInstanceId, TaskLibrary, VariantId};

use super::queue::{ReadyTask, RequestQueue};

/// One successfully launched task instance.
#[derive(Clone, Debug)]
pub struct Launch {
    /// Which instance.
    pub instance: TaskInstanceId,
    /// Task id.
    pub task: TaskId,
    /// Chosen variant.
    pub ver: VariantId,
    /// Allocated region.
    pub region: RegionId,
    /// Replication factor (fixed-size unrolling; 1 otherwise).
    pub replicas: u32,
    /// Launch cycle.
    pub start: u64,
    /// Reconfiguration cycles charged before execution.
    pub dpr_cycles: u64,
    /// Execution cycles (work / effective throughput).
    pub exec_cycles: u64,
    /// `start + dpr_cycles + exec_cycles`.
    pub finish: u64,
    /// Whether the bitstream was GLB-resident (fast-DPR hit).
    pub cache_hit: bool,
}

/// A variant option considered by the policy, with effective throughput.
#[derive(Clone, Debug)]
struct Option_ {
    ver: VariantId,
    eff_throughput: f64,
    /// Replication request (fixed-size only; 0 = plain allocation).
    replicate: u32,
    /// Fall back to exclusive whole-machine allocation.
    exclusive: bool,
}

/// Event-driven scheduler implementing the paper's greedy policy plus
/// FCFS and fair-share ablations.
#[derive(Clone, Debug)]
pub struct Scheduler {
    lib: TaskLibrary,
    mgr: RegionManager,
    dpr: DprEngine,
    policy: SchedulerPolicyKind,
    baseline_single_mapping: bool,
    /// region → instance, for completion handling.
    running: BTreeMap<RegionId, TaskInstanceId>,
    /// fair-share rotation cursor.
    rr_cursor: u32,
    /// pre-generated bitstreams per (task, variant).
    bitstreams: BTreeMap<BitstreamId, Bitstream>,
}

impl Scheduler {
    /// Build from a config; `mode` selects the DPR path (Fig. 5 compares
    /// AXI4-Lite for the baseline vs fast-DPR for the mechanisms).
    pub fn new(cfg: &Config, lib: TaskLibrary, mode: DprMode) -> Scheduler {
        let mgr = RegionManager::new(&cfg.arch, &cfg.scheduler);
        let dpr = DprEngine::new(&cfg.arch, &cfg.dpr, mode);
        let mut bitstreams = BTreeMap::new();
        for t in lib.iter() {
            for v in &t.variants {
                let bs = generate_bitstream(&t.id.0, v.ver.0, &v.demand, &cfg.arch, &cfg.dpr);
                bitstreams.insert(bs.id.clone(), bs);
            }
        }
        Scheduler {
            lib,
            mgr,
            dpr,
            policy: cfg.scheduler.policy,
            baseline_single_mapping: cfg.scheduler.baseline_single_mapping,
            running: BTreeMap::new(),
            rr_cursor: 0,
            bitstreams,
        }
    }

    /// Task library in use.
    pub fn library(&self) -> &TaskLibrary {
        &self.lib
    }

    /// Region manager (metrics want utilization/fragmentation).
    pub fn regions(&self) -> &RegionManager {
        &self.mgr
    }

    /// DPR engine (cache stats).
    pub fn dpr(&self) -> &DprEngine {
        &self.dpr
    }

    /// Preload every variant's bitstream into the GLB cache — the
    /// paper's "pre-load bitstreams of the next task in advance".
    pub fn preload_all(&mut self) {
        let all: Vec<Bitstream> = self.bitstreams.values().cloned().collect();
        for bs in &all {
            self.dpr.preload(bs);
        }
    }

    /// Scheduling step: launch every ready task that can be placed.
    /// Called on arrival and completion events.
    pub fn schedule(&mut self, queue: &mut RequestQueue, now: u64) -> Vec<Launch> {
        // Single pass: no completions happen inside a step, so resource
        // availability only shrinks — a task that failed to place cannot
        // succeed later in the same step, and tasks are independent.
        // (§Perf L3: a rescan-after-every-launch variant was O(ready²)
        // and dominated heavy-backlog simulations.)
        let ready = self.order_ready(queue.ready_tasks());
        let mut launches = Vec::new();
        for rt in ready {
            if let Some(launch) = self.try_launch(&rt, now) {
                queue.mark_launched(rt.instance).expect("ready implies launchable");
                launches.push(launch);
            }
        }
        if self.policy == SchedulerPolicyKind::FairShare {
            self.rr_cursor = self.rr_cursor.wrapping_add(1);
        }
        launches
    }

    /// Handle a task completion: free its region.  Returns the instance
    /// that was running there.
    pub fn complete(&mut self, region: RegionId) -> Result<TaskInstanceId> {
        let inst = self
            .running
            .remove(&region)
            .ok_or_else(|| Error::Sched(format!("completion for idle region {region}")))?;
        self.mgr.release(region)?;
        Ok(inst)
    }

    /// Number of running tasks.
    pub fn running_count(&self) -> usize {
        self.running.len()
    }

    // ------------------------------------------------------------- policy

    /// Order the ready list according to the task-selection policy.
    fn order_ready(&self, mut ready: Vec<ReadyTask>) -> Vec<ReadyTask> {
        match self.policy {
            // arrival order (request seq, then node) — queue order.
            SchedulerPolicyKind::GreedyThroughput | SchedulerPolicyKind::FcfsFirstFit => ready,
            SchedulerPolicyKind::FairShare => {
                // rotate tenants so each gets the head slot in turn
                let cursor = self.rr_cursor % 4;
                ready.sort_by_key(|r| ((r.tenant + 4 - cursor) % 4, r.instance));
                ready
            }
            SchedulerPolicyKind::ShortestJobFirst => {
                // shortest minimum execution time first; arrival breaks ties
                ready.sort_by_key(|r| {
                    let est = self
                        .lib
                        .get(&r.task)
                        .map(|t| t.exec_cycles(t.fastest()))
                        .unwrap_or(u64::MAX);
                    (est, r.instance)
                });
                ready
            }
        }
    }

    /// Enumerate variant options for a task in policy preference order.
    fn options_for(&self, task: &TaskId) -> Vec<Option_> {
        let spec = match self.lib.get(task) {
            Ok(s) => s,
            Err(_) => return Vec::new(),
        };
        let mut opts: Vec<Option_> = Vec::new();
        match self.mgr.policy() {
            RegionPolicyKind::Baseline => {
                // Whole machine per task.  With `baseline_single_mapping`
                // (the embedded Fig. 5 baseline) only the standard
                // variant-a bitstream exists; otherwise the baseline may
                // use any pre-compiled mapping (the generous cloud
                // baseline — keeps Fig. 4 margins conservative).
                if self.baseline_single_mapping {
                    let v = spec.smallest();
                    opts.push(Option_ {
                        ver: v.ver,
                        eff_throughput: v.throughput,
                        replicate: 0,
                        exclusive: true,
                    });
                } else {
                    for v in &spec.variants {
                        opts.push(Option_ {
                            ver: v.ver,
                            eff_throughput: v.throughput,
                            replicate: 0,
                            exclusive: true,
                        });
                    }
                }
            }
            RegionPolicyKind::FixedSize => {
                let unit = self.mgr.unit();
                let best_tpt = spec.fastest().throughput;
                for v in &spec.variants {
                    if v.demand.fits_within(&unit) {
                        opts.push(Option_ {
                            ver: v.ver,
                            eff_throughput: v.throughput,
                            replicate: 0,
                            exclusive: false,
                        });
                        // replication option: unroll copies across units
                        // up to the best pre-compiled mapping's speedup
                        // (no point unrolling beyond what variant b/c
                        // achieves with optimization).
                        let cap = (best_tpt / v.throughput).ceil() as u32;
                        if cap > 1 {
                            opts.push(Option_ {
                                ver: v.ver,
                                eff_throughput: v.throughput * cap as f64,
                                replicate: cap,
                                exclusive: false,
                            });
                        }
                    }
                }
                if opts.is_empty() {
                    // fits no unit: exclusive whole-machine fallback with
                    // every variant as a candidate.
                    for v in &spec.variants {
                        opts.push(Option_ {
                            ver: v.ver,
                            eff_throughput: v.throughput,
                            replicate: 0,
                            exclusive: true,
                        });
                    }
                }
            }
            RegionPolicyKind::VariableSize | RegionPolicyKind::FlexibleShape => {
                for v in &spec.variants {
                    opts.push(Option_ {
                        ver: v.ver,
                        eff_throughput: v.throughput,
                        replicate: 0,
                        exclusive: false,
                    });
                }
            }
        }
        match self.policy {
            SchedulerPolicyKind::GreedyThroughput
            | SchedulerPolicyKind::FairShare
            | SchedulerPolicyKind::ShortestJobFirst => {
                // paper: highest throughput first
                opts.sort_by(|a, b| b.eff_throughput.partial_cmp(&a.eff_throughput).unwrap());
            }
            SchedulerPolicyKind::FcfsFirstFit => {
                // smallest footprint first (ascending throughput proxy)
                opts.sort_by(|a, b| a.eff_throughput.partial_cmp(&b.eff_throughput).unwrap());
            }
        }
        opts
    }

    /// Try to launch one ready task; `None` if nothing fits right now.
    fn try_launch(&mut self, rt: &ReadyTask, now: u64) -> Option<Launch> {
        let options = self.options_for(&rt.task);
        for opt in options {
            let spec = self.lib.get(&rt.task).expect("options imply spec");
            let variant = spec.variant(opt.ver).expect("option from spec").clone();
            let outcome = if opt.exclusive {
                self.mgr.try_allocate_exclusive(&variant.demand)
            } else if opt.replicate > 1 {
                self.mgr.try_allocate_replicated(&variant.demand, opt.replicate)
            } else {
                self.mgr.try_allocate(&variant.demand)
            };
            let region: ExecutionRegion = match outcome {
                AllocOutcome::Allocated(r) => r,
                AllocOutcome::NoFit | AllocOutcome::NeverFits => continue,
            };

            // DPR: stream the variant's bitstream into the region.
            let bs_id = BitstreamId::new(rt.task.0.clone(), opt.ver.0);
            let bs = self.bitstreams.get(&bs_id).expect("pre-generated").clone();
            let dest = region.array.first().copied().unwrap_or(SliceRange::empty());
            let dpr_out = self.dpr.reconfigure(&bs, &dest);

            let replicas = region.replicas.max(1);
            let eff_tpt = variant.throughput * replicas as f64;
            let exec_cycles = (spec.work as f64 / eff_tpt).ceil() as u64;
            let finish = now + dpr_out.cycles + exec_cycles;

            self.running.insert(region.id, rt.instance);
            return Some(Launch {
                instance: rt.instance,
                task: rt.task.clone(),
                ver: opt.ver,
                region: region.id,
                replicas,
                start: now,
                dpr_cycles: dpr_out.cycles,
                exec_cycles,
                finish,
                cache_hit: dpr_out.cache_hit,
            });
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;
    use crate::tasks::{AppId, AppRequest};

    fn sched(policy: RegionPolicyKind) -> Scheduler {
        let cfg = presets::cloud_scenario(policy);
        Scheduler::new(&cfg, TaskLibrary::table1(), DprMode::Fast)
    }

    fn submit(q: &mut RequestQueue, seq: u64, tenant: u32, app: AppId, at: u64) {
        q.submit(AppRequest::new(seq, tenant, app, at));
    }

    #[test]
    fn greedy_picks_fastest_variant_when_idle() {
        let mut s = sched(RegionPolicyKind::FlexibleShape);
        s.preload_all();
        let mut q = RequestQueue::new();
        submit(&mut q, 0, 0, AppId::Harris, 0);
        let launches = s.schedule(&mut q, 0);
        assert_eq!(launches.len(), 1);
        assert_eq!(launches[0].ver, VariantId('c')); // 4 px/cyc, fastest
        assert!(launches[0].cache_hit);
        assert_eq!(s.running_count(), 1);
    }

    #[test]
    fn greedy_falls_back_to_smaller_variant_under_pressure() {
        let mut s = sched(RegionPolicyKind::FlexibleShape);
        s.preload_all();
        let mut q = RequestQueue::new();
        // camera b takes 14 GLB + 6 array; harris c (14 GLB + 7 array)
        // can then never fit (8 array total) — greedy drops to b then a.
        submit(&mut q, 0, 2, AppId::Camera, 0);
        submit(&mut q, 1, 3, AppId::Harris, 0);
        let launches = s.schedule(&mut q, 0);
        assert_eq!(launches.len(), 2);
        assert_eq!(launches[0].task.0, "camera.pipeline");
        assert_eq!(launches[0].ver, VariantId('b'));
        assert_eq!(launches[1].task.0, "harris.corner");
        // 2 array slices remain ⇒ only variant a (2 slices, 4 GLB) fits
        assert_eq!(launches[1].ver, VariantId('a'));
    }

    #[test]
    fn baseline_runs_one_task_at_a_time() {
        let mut s = sched(RegionPolicyKind::Baseline);
        let mut q = RequestQueue::new();
        submit(&mut q, 0, 0, AppId::Camera, 0);
        submit(&mut q, 1, 1, AppId::Harris, 0);
        let launches = s.schedule(&mut q, 0);
        assert_eq!(launches.len(), 1); // second task must wait
        assert_eq!(q.ready_count(), 1);

        // complete the first; next schedule launches the second
        let region = launches[0].region;
        let inst = s.complete(region).unwrap();
        q.mark_complete(inst, launches[0].finish).unwrap();
        let launches2 = s.schedule(&mut q, launches[0].finish);
        assert_eq!(launches2.len(), 1);
        assert_eq!(launches2[0].task.0, "harris.corner");
    }

    #[test]
    fn fixed_size_replicates_small_variants() {
        let mut s = sched(RegionPolicyKind::FixedSize);
        s.preload_all();
        let mut q = RequestQueue::new();
        submit(&mut q, 0, 1, AppId::MobileNet, 0);
        let launches = s.schedule(&mut q, 0);
        assert_eq!(launches.len(), 1);
        let l = &launches[0];
        // group 2's variant b (208 = 4×52) needs 5 array slices > unit;
        // greedy instead replicates variant a across 4 units (4×52=208).
        assert_eq!(l.ver, VariantId('a'));
        assert_eq!(l.replicas, 4);
    }

    #[test]
    fn fixed_size_exclusive_fallback_for_oversized() {
        let mut s = sched(RegionPolicyKind::FixedSize);
        s.preload_all();
        let mut q = RequestQueue::new();
        // camera a needs (4 GLB, 4 array) > unit (8, 2) in array dim
        submit(&mut q, 0, 2, AppId::Camera, 0);
        let launches = s.schedule(&mut q, 0);
        assert_eq!(launches.len(), 1);
        // exclusive: the whole machine is taken
        assert_eq!(s.regions().active_count(), 1);
        let (ug, ua) = s.regions().utilization();
        assert_eq!((ug, ua), (1.0, 1.0));
    }

    #[test]
    fn completion_unblocks_chain_successor() {
        let mut s = sched(RegionPolicyKind::FlexibleShape);
        s.preload_all();
        let mut q = RequestQueue::new();
        submit(&mut q, 0, 0, AppId::ResNet18, 0);
        let l1 = s.schedule(&mut q, 0);
        assert_eq!(l1.len(), 1);
        assert_eq!(l1[0].task.0, "resnet18.conv2_x");
        // conv3 not ready until conv2 completes
        assert_eq!(q.ready_count(), 0);
        let inst = s.complete(l1[0].region).unwrap();
        q.mark_complete(inst, l1[0].finish).unwrap();
        let l2 = s.schedule(&mut q, l1[0].finish);
        assert_eq!(l2.len(), 1);
        assert_eq!(l2[0].task.0, "resnet18.conv3_x");
    }

    #[test]
    fn fcfs_policy_prefers_smallest_variant() {
        let cfg = {
            let mut c = presets::cloud_scenario(RegionPolicyKind::FlexibleShape);
            c.scheduler.policy = SchedulerPolicyKind::FcfsFirstFit;
            c
        };
        let mut s = Scheduler::new(&cfg, TaskLibrary::table1(), DprMode::Fast);
        let mut q = RequestQueue::new();
        submit(&mut q, 0, 3, AppId::Harris, 0);
        let launches = s.schedule(&mut q, 0);
        assert_eq!(launches[0].ver, VariantId('a'));
    }

    #[test]
    fn complete_unknown_region_errors() {
        let mut s = sched(RegionPolicyKind::FlexibleShape);
        assert!(s.complete(RegionId(42)).is_err());
    }

    #[test]
    fn exec_cycles_match_table1_math() {
        let mut s = sched(RegionPolicyKind::FlexibleShape);
        s.preload_all();
        let mut q = RequestQueue::new();
        submit(&mut q, 0, 2, AppId::Camera, 0);
        let l = &s.schedule(&mut q, 0)[0];
        // camera b: 2,073,600 px / 12 px-per-cycle = 172,800 cycles
        assert_eq!(l.exec_cycles, 172_800);
        assert_eq!(l.finish, l.start + l.dpr_cycles + l.exec_cycles);
    }
}
