//! Run-time scheduler: allocates slices to ready tasks (§2.2–3.1).
//!
//! "At run time, a scheduler leverages the hardware slice abstraction to
//! decide which variant of tasks to choose, which resources to allocate,
//! and when to execute."
//!
//! The scheduler is event-driven: the simulation (or the live
//! coordinator) calls [`Scheduler::schedule`] whenever a task arrives or
//! finishes (§3.1: "whenever a new task arrives, or an existing task
//! finishes, the scheduler is triggered"), and the scheduler launches
//! every ready task it can place, going through:
//!
//! 1. variant selection under the configured policy (paper: greedy
//!    highest-throughput-that-fits),
//! 2. region allocation under the configured mechanism ([`crate::regions`]),
//! 3. DPR cost accounting ([`crate::dpr`]), and
//! 4. execution-time computation from Table 1 throughputs.
//!
//! When every variant of a ready task returns `NoFit` and
//! `scheduler.defrag_policy` is enabled, the scheduler additionally
//! consults the defragmentation planner ([`crate::migration`]) and may
//! live-migrate running tasks to open a contiguous hole before giving
//! up on the task for this step.
//!
//! With the QoS subsystem enabled ([`crate::qos`]), the ready frontier
//! is ordered by strict class priority + EDF instead of the base
//! policy, and a still-blocked higher-class task may checkpoint-and-
//! evict running strictly-lower-class tasks; the victims resume later
//! from their checkpoints with their remaining cycles.

mod core;
mod queue;

pub use core::{CompletionOutcome, Launch, Scheduler};
pub use queue::{ReadyTask, RequestQueue};
