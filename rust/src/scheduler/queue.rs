//! Request queue with dependency tracking.

use std::collections::BTreeMap;

use crate::config::QosClass;
use crate::error::{Error, Result};
use crate::tasks::{AppGraph, AppRequest, TaskId, TaskInstanceId};

/// A task whose dependencies are satisfied and which awaits resources.
#[derive(Clone, Debug, PartialEq)]
pub struct ReadyTask {
    /// Instance identity (request seq + node index).
    pub instance: TaskInstanceId,
    /// Task to run.
    pub task: TaskId,
    /// Submitting tenant.
    pub tenant: u32,
    /// Cycle at which the instance became ready (dependencies met).
    pub ready_cycle: u64,
    /// Cycle at which the *request* arrived (for TAT).
    pub arrival_cycle: u64,
    /// QoS class of the owning request ([`crate::qos`]).
    pub class: QosClass,
    /// Absolute deadline of the owning request, if any.
    pub deadline: Option<u64>,
    /// Bytes this node streams in from its graph predecessors before it
    /// can compute ([`AppGraph::stream_in_bytes`]); priced by the NoC
    /// contention model at launch.
    pub stream_in_bytes: u64,
}

/// In-flight application requests and their ready frontier.
#[derive(Clone, Debug, Default)]
pub struct RequestQueue {
    requests: BTreeMap<u64, AppRequest>,
    graphs: BTreeMap<u64, AppGraph>,
    /// instance → ready cycle, for instances whose deps are met and which
    /// haven't been launched yet.
    ready: BTreeMap<TaskInstanceId, u64>,
    /// instances currently running (launched, not complete).
    running: BTreeMap<TaskInstanceId, ()>,
    /// One past the highest tenant id ever submitted (monotone) — the
    /// fair-share rotation modulus is derived from this, not from a
    /// hard-coded tenant count.
    tenant_span: u32,
}

impl RequestQueue {
    /// Empty queue.
    pub fn new() -> Self {
        Self::default()
    }

    /// Admit a request; its root task(s) become ready immediately.
    pub fn submit(&mut self, req: AppRequest) {
        self.tenant_span = self.tenant_span.max(req.tenant + 1);
        let graph = AppGraph::of(req.app);
        for node in req.ready_nodes(&graph) {
            self.ready
                .insert(TaskInstanceId { request: req.seq, node }, req.arrival_cycle);
        }
        self.graphs.insert(req.seq, graph);
        self.requests.insert(req.seq, req);
    }

    /// Ready tasks in arrival order (request seq, then node index).
    pub fn ready_tasks(&self) -> Vec<ReadyTask> {
        self.ready
            .iter()
            .map(|(inst, &ready_cycle)| {
                let req = &self.requests[&inst.request];
                let graph = &self.graphs[&inst.request];
                ReadyTask {
                    instance: *inst,
                    task: graph.nodes[inst.node].clone(),
                    tenant: req.tenant,
                    ready_cycle,
                    arrival_cycle: req.arrival_cycle,
                    class: req.class,
                    deadline: req.deadline,
                    stream_in_bytes: graph.stream_in_bytes[inst.node],
                }
            })
            .collect()
    }

    /// Number of ready (waiting) tasks.
    pub fn ready_count(&self) -> usize {
        self.ready.len()
    }

    /// One past the highest tenant id ever submitted (0 before any
    /// submission).  Monotone over the queue's lifetime, so round-robin
    /// rotations derived from it stay stable as requests drain.
    pub fn tenant_span(&self) -> u32 {
        self.tenant_span
    }

    /// Number of running tasks.
    pub fn running_count(&self) -> usize {
        self.running.len()
    }

    /// Number of incomplete requests.
    pub fn open_requests(&self) -> usize {
        self.requests.len()
    }

    /// Open (incomplete) requests per tenant — backlog introspection for
    /// the multi-tenant serving front (the leader reports it when a
    /// batch fails mid-serve and strands admitted requests).
    pub fn open_requests_by_tenant(&self) -> BTreeMap<u32, usize> {
        let mut out = BTreeMap::new();
        for req in self.requests.values() {
            *out.entry(req.tenant).or_insert(0) += 1;
        }
        out
    }

    /// Mark an instance as launched (moves ready → running).
    pub fn mark_launched(&mut self, inst: TaskInstanceId) -> Result<()> {
        self.ready
            .remove(&inst)
            .ok_or_else(|| Error::Sched(format!("{inst} launched but not ready")))?;
        self.running.insert(inst, ());
        Ok(())
    }

    /// Move a *running* instance back to the ready frontier at `now` —
    /// the checkpointed-eviction path ([`crate::qos`]).  The instance's
    /// completion state is untouched, so its graph successors stay
    /// blocked and the request completes exactly once, after the resumed
    /// instance finishes.
    pub fn mark_preempted(&mut self, inst: TaskInstanceId, now: u64) -> Result<()> {
        self.running
            .remove(&inst)
            .ok_or_else(|| Error::Sched(format!("{inst} preempted but not running")))?;
        self.ready.insert(inst, now);
        Ok(())
    }

    /// Mark an instance complete at `now`; newly-unblocked successors
    /// become ready.  Returns the owning request when it fully completed.
    pub fn mark_complete(&mut self, inst: TaskInstanceId, now: u64) -> Result<Option<AppRequest>> {
        self.running
            .remove(&inst)
            .ok_or_else(|| Error::Sched(format!("{inst} completed but not running")))?;
        let req = self
            .requests
            .get_mut(&inst.request)
            .ok_or_else(|| Error::Sched(format!("{inst} has no request")))?;
        if req.done[inst.node] {
            return Err(Error::SimInvariant(format!("{inst} completed twice")));
        }
        req.done[inst.node] = true;
        let graph = &self.graphs[&inst.request];
        // successors whose deps are all met and not yet ready/running
        for node in req.ready_nodes(graph) {
            let succ = TaskInstanceId { request: inst.request, node };
            if !self.running.contains_key(&succ) {
                self.ready.entry(succ).or_insert(now);
            }
        }
        if req.complete() {
            let done = self.requests.remove(&inst.request).expect("present");
            self.graphs.remove(&inst.request);
            Ok(Some(done))
        } else {
            Ok(None)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tasks::AppId;

    #[test]
    fn chain_progresses_node_by_node() {
        let mut q = RequestQueue::new();
        q.submit(AppRequest::new(0, 2, AppId::MobileNet, 100));
        assert_eq!(q.ready_count(), 1);
        let r = &q.ready_tasks()[0];
        assert_eq!(r.task.0, "mobilenet.conv_dw_pw_2_x");
        assert_eq!(r.ready_cycle, 100);
        assert_eq!(r.tenant, 2);

        let inst = r.instance;
        q.mark_launched(inst).unwrap();
        assert_eq!(q.ready_count(), 0);
        assert_eq!(q.running_count(), 1);

        let done = q.mark_complete(inst, 500).unwrap();
        assert!(done.is_none());
        assert_eq!(q.ready_count(), 1);
        let r2 = &q.ready_tasks()[0];
        assert_eq!(r2.task.0, "mobilenet.conv_dw_pw_3_x");
        assert_eq!(r2.ready_cycle, 500); // becomes ready at completion time
        assert_eq!(r2.arrival_cycle, 100); // TAT anchored to request arrival
    }

    #[test]
    fn request_completion_returned() {
        let mut q = RequestQueue::new();
        q.submit(AppRequest::new(7, 0, AppId::Camera, 0));
        let inst = q.ready_tasks()[0].instance;
        q.mark_launched(inst).unwrap();
        let done = q.mark_complete(inst, 42).unwrap().expect("request complete");
        assert_eq!(done.seq, 7);
        assert_eq!(q.open_requests(), 0);
    }

    #[test]
    fn multiple_requests_ready_in_arrival_order() {
        let mut q = RequestQueue::new();
        q.submit(AppRequest::new(0, 0, AppId::Harris, 10));
        q.submit(AppRequest::new(1, 1, AppId::Camera, 20));
        let ready = q.ready_tasks();
        assert_eq!(ready.len(), 2);
        assert_eq!(ready[0].instance.request, 0);
        assert_eq!(ready[1].instance.request, 1);
    }

    #[test]
    fn backlog_tracked_per_tenant() {
        let mut q = RequestQueue::new();
        q.submit(AppRequest::new(0, 0, AppId::Harris, 0));
        q.submit(AppRequest::new(1, 2, AppId::Camera, 1));
        q.submit(AppRequest::new(2, 2, AppId::Harris, 2));
        let by_tenant = q.open_requests_by_tenant();
        assert_eq!(by_tenant.get(&0), Some(&1));
        assert_eq!(by_tenant.get(&2), Some(&2));
        assert_eq!(by_tenant.get(&1), None);
        // completing tenant 0's single-task request clears its backlog
        let inst = q
            .ready_tasks()
            .iter()
            .find(|r| r.tenant == 0)
            .unwrap()
            .instance;
        q.mark_launched(inst).unwrap();
        q.mark_complete(inst, 5).unwrap();
        assert_eq!(q.open_requests_by_tenant().get(&0), None);
    }

    #[test]
    fn protocol_violations_error() {
        let mut q = RequestQueue::new();
        q.submit(AppRequest::new(0, 0, AppId::Camera, 0));
        let inst = q.ready_tasks()[0].instance;
        assert!(q.mark_complete(inst, 1).is_err()); // not launched yet
        assert!(q.mark_preempted(inst, 1).is_err()); // not running yet
        q.mark_launched(inst).unwrap();
        assert!(q.mark_launched(inst).is_err()); // double launch
        q.mark_complete(inst, 1).unwrap();
        assert!(q.mark_complete(inst, 2).is_err()); // double complete
    }

    #[test]
    fn preemption_cycles_running_back_to_ready_and_completes_once() {
        use crate::config::QosClass;
        let mut q = RequestQueue::new();
        q.submit(
            AppRequest::new(0, 3, AppId::Harris, 10).with_qos(QosClass::Critical, Some(500)),
        );
        let rt = q.ready_tasks()[0].clone();
        assert_eq!(rt.class, QosClass::Critical);
        assert_eq!(rt.deadline, Some(500));
        q.mark_launched(rt.instance).unwrap();
        // evict: instance returns to ready with a fresh ready cycle
        q.mark_preempted(rt.instance, 200).unwrap();
        assert_eq!(q.ready_count(), 1);
        assert_eq!(q.running_count(), 0);
        let again = &q.ready_tasks()[0];
        assert_eq!(again.ready_cycle, 200);
        assert_eq!(again.arrival_cycle, 10, "TAT stays anchored to arrival");
        assert_eq!(again.class, QosClass::Critical);
        // resume + complete exactly once
        q.mark_launched(again.instance).unwrap();
        let done = q.mark_complete(rt.instance, 400).unwrap();
        assert!(done.is_some(), "single-task request completes");
        assert!(q.mark_complete(rt.instance, 401).is_err());
    }
}
