//! Structural model of the baseline CGRA (paper §2.1, Fig. 1).
//!
//! This is the substrate under the slice abstraction: a 32×16 tile array
//! of PE and MEM tiles on a statically-configured mesh, fronted by a
//! 32-bank global buffer whose banks talk to the array through IO tiles.
//! The simulator never needs per-tile cycle behaviour (scheduling and DPR
//! operate at slice granularity), but the structural model grounds the
//! bitstream sizes, slice homogeneity checks, and the Fig. 1 / Fig. 2
//! renders, and gives the compiler real tile coordinates to map onto.

mod clock;
mod geometry;
mod glb;
mod interconnect;
mod tile;

pub use clock::{Clock, ClockTree};
pub use geometry::{Geometry, SliceGeometry};
pub use glb::{GlbBank, GlobalBuffer};
pub use interconnect::{Interconnect, RouteEstimate};
pub use tile::{Tile, TileCoord, TileKind};
