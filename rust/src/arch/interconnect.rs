//! Statically-configured mesh interconnect (paper §2.1).
//!
//! Five tracks per direction; switch boxes route incoming→outgoing
//! tracks, connection boxes tap tracks into tile cores.  The simulator
//! works at slice granularity, so this model answers only the questions
//! the rest of the system asks:
//!  * how many config words does routing contribute to a bitstream, and
//!  * is a route between a GLB column and a region feasible / how long —
//!    used by the flexible-shape mechanism to cost non-square regions
//!    (the paper flags GLB↔array communication as the price of
//!    decoupling, §2.3).

use crate::config::ArchConfig;

/// Mesh interconnect parameters.
#[derive(Clone, Debug)]
pub struct Interconnect {
    tracks_per_dir: u32,
    cols: u32,
    rows: u32,
}

/// Result of a route estimate.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RouteEstimate {
    /// Manhattan hop count from the source IO column to the region.
    pub hops: u32,
    /// Whether the route fits in the available track budget.
    pub feasible: bool,
}

impl Interconnect {
    /// Build from architecture parameters.
    pub fn new(arch: &ArchConfig) -> Self {
        Interconnect {
            tracks_per_dir: arch.tracks_per_dir,
            cols: arch.cols,
            rows: arch.rows,
        }
    }

    /// Tracks per direction (paper: 5).
    pub fn tracks_per_dir(&self) -> u32 {
        self.tracks_per_dir
    }

    /// Estimate a route from a GLB IO column to a destination column.
    ///
    /// Data enters at the top of `io_col` and travels horizontally along
    /// the top row then down the destination column; each extra
    /// concurrent stream through the same corridor consumes one track.
    pub fn route(&self, io_col: u32, dest_col: u32, concurrent_streams: u32) -> RouteEstimate {
        let io_col = io_col.min(self.cols.saturating_sub(1));
        let dest_col = dest_col.min(self.cols.saturating_sub(1));
        let horiz = io_col.abs_diff(dest_col);
        let hops = horiz + self.rows / 2; // average vertical descent
        RouteEstimate { hops, feasible: concurrent_streams < self.tracks_per_dir }
    }

    /// Config words contributed by routing per tile (switch box +
    /// connection boxes); scales with track count.
    pub fn route_words_per_tile(&self, base_words: u32) -> u32 {
        // base is calibrated for 5 tracks; scale linearly.
        (base_words * self.tracks_per_dir).div_ceil(5)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ic() -> Interconnect {
        Interconnect::new(&ArchConfig::default())
    }

    #[test]
    fn straight_down_route_is_short() {
        let r = ic().route(4, 4, 0);
        assert!(r.feasible);
        assert_eq!(r.hops, 8); // vertical average only
    }

    #[test]
    fn horizontal_distance_adds_hops() {
        let near = ic().route(0, 2, 0).hops;
        let far = ic().route(0, 30, 0).hops;
        assert!(far > near);
        assert_eq!(far - near, 28);
    }

    #[test]
    fn track_budget_limits_streams() {
        let i = ic();
        assert!(i.route(0, 8, 4).feasible);
        assert!(!i.route(0, 8, 5).feasible);
    }

    #[test]
    fn route_words_scale_with_tracks() {
        let mut arch = ArchConfig::default();
        assert_eq!(Interconnect::new(&arch).route_words_per_tile(32), 32);
        arch.tracks_per_dir = 10;
        assert_eq!(Interconnect::new(&arch).route_words_per_tile(32), 64);
    }

    #[test]
    fn out_of_range_cols_clamped() {
        let r = ic().route(999, 999, 0);
        assert_eq!(r.hops, 8);
    }
}
