//! Statically-configured mesh interconnect (paper §2.1).
//!
//! Five tracks per direction; switch boxes route incoming→outgoing
//! tracks, connection boxes tap tracks into tile cores.  The simulator
//! works at slice granularity, so this model answers only the questions
//! the rest of the system asks:
//!  * how many config words does routing contribute to a bitstream, and
//!  * is a route between a GLB column and a region feasible / how long —
//!    used by the flexible-shape mechanism to cost non-square regions
//!    (the paper flags GLB↔array communication as the price of
//!    decoupling, §2.3).

use crate::config::ArchConfig;

/// Mesh interconnect parameters.
#[derive(Clone, Debug)]
pub struct Interconnect {
    tracks_per_dir: u32,
    cols: u32,
    rows: u32,
}

/// Result of a route estimate.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RouteEstimate {
    /// Manhattan hop count from the source IO column to the region.
    pub hops: u32,
    /// Whether the route fits in the available track budget.
    pub feasible: bool,
}

impl Interconnect {
    /// Build from architecture parameters.
    pub fn new(arch: &ArchConfig) -> Self {
        Interconnect {
            tracks_per_dir: arch.tracks_per_dir,
            cols: arch.cols,
            rows: arch.rows,
        }
    }

    /// Tracks per direction (paper: 5).
    pub fn tracks_per_dir(&self) -> u32 {
        self.tracks_per_dir
    }

    /// Estimate a route from a GLB IO column to a destination column.
    ///
    /// Data enters at the top of `io_col` and travels horizontally along
    /// the top row then down the destination column through the
    /// region's `dest_rows` occupied rows; each extra concurrent stream
    /// through the same corridor consumes one track.
    ///
    /// Columns outside the fabric are a caller-geometry bug: debug
    /// builds assert, release builds report the route infeasible rather
    /// than inventing a short route to a clamped column.
    pub fn route(
        &self,
        io_col: u32,
        dest_col: u32,
        dest_rows: u32,
        concurrent_streams: u32,
    ) -> RouteEstimate {
        debug_assert!(
            io_col < self.cols && dest_col < self.cols,
            "route columns ({io_col}, {dest_col}) outside fabric of {} cols",
            self.cols
        );
        if io_col >= self.cols || dest_col >= self.cols {
            return RouteEstimate { hops: u32::MAX, feasible: false };
        }
        let horiz = io_col.abs_diff(dest_col);
        let hops = horiz + dest_rows.min(self.rows);
        RouteEstimate { hops, feasible: concurrent_streams < self.tracks_per_dir }
    }

    /// Config words contributed by routing per tile (switch box +
    /// connection boxes); scales with track count.
    pub fn route_words_per_tile(&self, base_words: u32) -> u32 {
        // base is calibrated for 5 tracks; scale linearly.
        (base_words * self.tracks_per_dir).div_ceil(5)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ic() -> Interconnect {
        Interconnect::new(&ArchConfig::default())
    }

    #[test]
    fn straight_down_route_is_short() {
        let r = ic().route(4, 4, 16, 0);
        assert!(r.feasible);
        assert_eq!(r.hops, 16); // full-height descent, no horizontal hops
    }

    #[test]
    fn vertical_cost_tracks_region_row_span() {
        // A shallow region prices cheaper than a full-height one.
        let shallow = ic().route(4, 4, 4, 0).hops;
        let tall = ic().route(4, 4, 16, 0).hops;
        assert_eq!(shallow, 4);
        assert_eq!(tall, 16);
        // ... and the span is capped at the fabric height.
        assert_eq!(ic().route(4, 4, 99, 0).hops, 16);
    }

    #[test]
    fn horizontal_distance_adds_hops() {
        let near = ic().route(0, 2, 16, 0).hops;
        let far = ic().route(0, 30, 16, 0).hops;
        assert!(far > near);
        assert_eq!(far - near, 28);
    }

    #[test]
    fn track_budget_limits_streams() {
        let i = ic();
        assert!(i.route(0, 8, 16, 4).feasible);
        assert!(!i.route(0, 8, 16, 5).feasible);
    }

    #[test]
    fn route_words_scale_with_tracks() {
        let mut arch = ArchConfig::default();
        assert_eq!(Interconnect::new(&arch).route_words_per_tile(32), 32);
        arch.tracks_per_dir = 10;
        assert_eq!(Interconnect::new(&arch).route_words_per_tile(32), 64);
    }

    // Out-of-range columns are a caller bug: debug builds assert
    // loudly, release builds refuse the route instead of silently
    // clamping to a fake short route (the old behavior).
    #[test]
    #[cfg_attr(debug_assertions, should_panic(expected = "outside fabric"))]
    fn out_of_range_cols_are_infeasible() {
        let r = ic().route(999, 999, 16, 0);
        assert!(!r.feasible);
        assert_eq!(r.hops, u32::MAX);
    }
}
