//! Tiles: the unit cells of the CGRA array.

use std::fmt;

/// What a tile does (paper §2.1).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum TileKind {
    /// Processing element: word-level ALU extended with MAC (Amber-style).
    Pe,
    /// Memory tile: small scratchpad SRAM used as line/double buffers.
    Mem,
    /// IO tile: sits at the top row of a column group, bridges a GLB bank
    /// to the array.
    Io,
}

impl TileKind {
    /// Short glyph for array renders.
    pub fn glyph(&self) -> char {
        match self {
            TileKind::Pe => 'P',
            TileKind::Mem => 'M',
            TileKind::Io => 'I',
        }
    }
}

/// Column/row coordinate in the tile array (col-major like the paper's
/// column-oriented configuration streaming).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct TileCoord {
    /// Column index (0-based, left to right).
    pub col: u32,
    /// Row index (0-based, top to bottom).
    pub row: u32,
}

impl fmt::Display for TileCoord {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "({},{})", self.col, self.row)
    }
}

/// One tile instance.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Tile {
    /// Function of the tile.
    pub kind: TileKind,
    /// Position.
    pub coord: TileCoord,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn glyphs_are_distinct() {
        let glyphs = [TileKind::Pe.glyph(), TileKind::Mem.glyph(), TileKind::Io.glyph()];
        let mut dedup = glyphs.to_vec();
        dedup.dedup();
        assert_eq!(dedup.len(), 3);
    }

    #[test]
    fn coord_ordering_is_col_major() {
        let a = TileCoord { col: 0, row: 5 };
        let b = TileCoord { col: 1, row: 0 };
        assert!(a < b);
    }
}
