//! Array geometry: tile layout and slice carving.

use crate::abstraction::ArraySliceId;
use crate::config::ArchConfig;
use crate::error::{Error, Result};

use super::tile::{Tile, TileCoord, TileKind};

/// Fully-elaborated tile-array geometry.
#[derive(Clone, Debug)]
pub struct Geometry {
    arch: ArchConfig,
    /// col-major tile matrix, `cols × rows`.
    tiles: Vec<Tile>,
}

/// Per-slice structural summary; all slices must be identical
/// (homogeneity is what makes slices interchangeable for relocation).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SliceGeometry {
    /// PE tiles per slice.
    pub pe_tiles: u32,
    /// MEM tiles per slice.
    pub mem_tiles: u32,
    /// Columns per slice.
    pub cols: u32,
    /// GLB banks fronting the slice.
    pub glb_banks: u32,
}

impl Geometry {
    /// Elaborate from a validated config.
    pub fn new(arch: &ArchConfig) -> Result<Geometry> {
        arch.validate()?;
        let mut tiles = Vec::with_capacity((arch.cols * arch.rows) as usize);
        for col in 0..arch.cols {
            // every `mem_col_period`-th column is a MEM column; the last
            // column of each period so a slice reads P P P M (Amber-like).
            let is_mem = (col + 1) % arch.mem_col_period == 0;
            for row in 0..arch.rows {
                let kind = if is_mem { TileKind::Mem } else { TileKind::Pe };
                tiles.push(Tile { kind, coord: TileCoord { col, row } });
            }
        }
        Ok(Geometry { arch: arch.clone(), tiles })
    }

    /// Architecture parameters this geometry was built from.
    pub fn arch(&self) -> &ArchConfig {
        &self.arch
    }

    /// Tile at a coordinate.
    pub fn tile(&self, coord: TileCoord) -> Result<&Tile> {
        if coord.col >= self.arch.cols || coord.row >= self.arch.rows {
            return Err(Error::Config(format!("tile {coord} out of bounds")));
        }
        Ok(&self.tiles[(coord.col * self.arch.rows + coord.row) as usize])
    }

    /// All tiles, col-major.
    pub fn tiles(&self) -> &[Tile] {
        &self.tiles
    }

    /// Column range `[start, end)` of an array-slice.
    pub fn slice_cols(&self, slice: ArraySliceId) -> std::ops::Range<u32> {
        let start = slice.0 * self.arch.slice_cols;
        start..start + self.arch.slice_cols
    }

    /// The array-slice owning a column.
    pub fn slice_of_col(&self, col: u32) -> ArraySliceId {
        ArraySliceId(col / self.arch.slice_cols)
    }

    /// Tiles belonging to one array-slice.
    pub fn slice_tiles(&self, slice: ArraySliceId) -> impl Iterator<Item = &Tile> {
        let cols = self.slice_cols(slice);
        self.tiles
            .iter()
            .filter(move |t| cols.contains(&t.coord.col))
    }

    /// Structural summary of one slice.
    pub fn slice_geometry(&self, slice: ArraySliceId) -> SliceGeometry {
        let (mut pe, mut mem) = (0u32, 0u32);
        for t in self.slice_tiles(slice) {
            match t.kind {
                TileKind::Pe => pe += 1,
                TileKind::Mem => mem += 1,
                TileKind::Io => {}
            }
        }
        SliceGeometry {
            pe_tiles: pe,
            mem_tiles: mem,
            cols: self.arch.slice_cols,
            glb_banks: self.arch.glb_banks / self.arch.array_slices(),
        }
    }

    /// Check every slice is structurally identical — the precondition for
    /// region-agnostic bitstreams (paper §2.3 relocation).
    pub fn slices_homogeneous(&self) -> bool {
        let n = self.arch.array_slices();
        if n == 0 {
            return true;
        }
        let first = self.slice_geometry(ArraySliceId(0));
        (1..n).all(|i| self.slice_geometry(ArraySliceId(i)) == first)
    }

    /// ASCII render of the tile array (Fig. 1 style), one row per line.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for row in 0..self.arch.rows {
            for col in 0..self.arch.cols {
                let t = &self.tiles[(col * self.arch.rows + row) as usize];
                out.push(t.kind.glyph());
                if (col + 1) % self.arch.slice_cols == 0 && col + 1 != self.arch.cols {
                    out.push('|'); // slice boundary
                }
            }
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn paper_geom() -> Geometry {
        Geometry::new(&ArchConfig::default()).unwrap()
    }

    #[test]
    fn paper_tile_counts() {
        let g = paper_geom();
        let pe = g.tiles().iter().filter(|t| t.kind == TileKind::Pe).count();
        let mem = g.tiles().iter().filter(|t| t.kind == TileKind::Mem).count();
        assert_eq!(pe, 384);
        assert_eq!(mem, 128);
    }

    #[test]
    fn slice_geometry_matches_paper() {
        let g = paper_geom();
        let sg = g.slice_geometry(ArraySliceId(0));
        assert_eq!(sg.pe_tiles, 48);
        assert_eq!(sg.mem_tiles, 16);
        assert_eq!(sg.cols, 4);
        assert_eq!(sg.glb_banks, 4);
    }

    #[test]
    fn slices_are_homogeneous() {
        assert!(paper_geom().slices_homogeneous());
    }

    #[test]
    fn slice_col_mapping() {
        let g = paper_geom();
        assert_eq!(g.slice_cols(ArraySliceId(0)), 0..4);
        assert_eq!(g.slice_cols(ArraySliceId(7)), 28..32);
        assert_eq!(g.slice_of_col(0), ArraySliceId(0));
        assert_eq!(g.slice_of_col(31), ArraySliceId(7));
    }

    #[test]
    fn tile_lookup_bounds() {
        let g = paper_geom();
        assert!(g.tile(TileCoord { col: 31, row: 15 }).is_ok());
        assert!(g.tile(TileCoord { col: 32, row: 0 }).is_err());
        assert!(g.tile(TileCoord { col: 0, row: 16 }).is_err());
    }

    #[test]
    fn mem_columns_every_fourth() {
        let g = paper_geom();
        for col in 0..32u32 {
            let expect_mem = (col + 1) % 4 == 0;
            let t = g.tile(TileCoord { col, row: 0 }).unwrap();
            assert_eq!(t.kind == TileKind::Mem, expect_mem, "col {col}");
        }
    }

    #[test]
    fn render_has_slice_separators() {
        let g = paper_geom();
        let render = g.render();
        let first_line = render.lines().next().unwrap();
        assert_eq!(first_line, "PPPM|PPPM|PPPM|PPPM|PPPM|PPPM|PPPM|PPPM");
    }
}
