//! Global buffer: banks, bank state, and the GLB⇄slice association.
//!
//! Each GLB bank (paper: 32 × 128 KB SRAM) plays three roles the
//! mechanisms care about:
//!  * data staging for the task mapped to the region it belongs to,
//!  * bitstream storage for fast-DPR (a bank can cache a pre-loaded
//!    bitstream and stream it into an array-slice, §2.3), and
//!  * host DMA endpoint.

use crate::abstraction::{ArraySliceId, GlbSliceId};
use crate::config::ArchConfig;
use crate::error::{Error, Result};

/// What a bank's SRAM currently holds (coarse; capacity accounting only).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct GlbBank {
    /// Bytes of task data resident.
    pub data_bytes: u64,
    /// Bytes of cached bitstream resident (fast-DPR storage role).
    pub bitstream_bytes: u64,
    /// Capacity in bytes.
    pub capacity: u64,
}

impl GlbBank {
    /// Empty bank of `capacity` bytes.
    pub fn new(capacity: u64) -> Self {
        GlbBank { data_bytes: 0, bitstream_bytes: 0, capacity }
    }

    /// Bytes still free.
    pub fn free_bytes(&self) -> u64 {
        self.capacity.saturating_sub(self.data_bytes + self.bitstream_bytes)
    }

    /// Reserve task-data bytes.
    pub fn alloc_data(&mut self, bytes: u64) -> Result<()> {
        if bytes > self.free_bytes() {
            return Err(Error::Alloc(format!(
                "GLB bank overflow: want {bytes} B, free {} B",
                self.free_bytes()
            )));
        }
        self.data_bytes += bytes;
        Ok(())
    }

    /// Release task-data bytes.
    pub fn free_data(&mut self, bytes: u64) {
        debug_assert!(bytes <= self.data_bytes, "freeing more data than allocated");
        self.data_bytes = self.data_bytes.saturating_sub(bytes);
    }

    /// Reserve bitstream-cache bytes (fast-DPR preload).
    pub fn alloc_bitstream(&mut self, bytes: u64) -> Result<()> {
        if bytes > self.free_bytes() {
            return Err(Error::Alloc(format!(
                "GLB bank bitstream overflow: want {bytes} B, free {} B",
                self.free_bytes()
            )));
        }
        self.bitstream_bytes += bytes;
        Ok(())
    }

    /// Evict cached bitstream bytes.
    pub fn free_bitstream(&mut self, bytes: u64) {
        debug_assert!(bytes <= self.bitstream_bytes);
        self.bitstream_bytes = self.bitstream_bytes.saturating_sub(bytes);
    }
}

/// The whole GLB: `glb_banks` banks plus the static bank→slice topology.
#[derive(Clone, Debug)]
pub struct GlobalBuffer {
    banks: Vec<GlbBank>,
    banks_per_slice: u32,
}

impl GlobalBuffer {
    /// Build from architecture parameters.
    pub fn new(arch: &ArchConfig) -> GlobalBuffer {
        let banks = (0..arch.glb_banks)
            .map(|_| GlbBank::new(arch.glb_slice_bytes()))
            .collect();
        GlobalBuffer { banks, banks_per_slice: arch.glb_banks / arch.array_slices() }
    }

    /// Bank count.
    pub fn len(&self) -> u32 {
        self.banks.len() as u32
    }

    /// True if the GLB has no banks (degenerate configs only).
    pub fn is_empty(&self) -> bool {
        self.banks.is_empty()
    }

    /// Bank accessor.
    pub fn bank(&self, id: GlbSliceId) -> Result<&GlbBank> {
        self.banks
            .get(id.0 as usize)
            .ok_or_else(|| Error::Config(format!("GLB bank {id} out of range")))
    }

    /// Mutable bank accessor.
    pub fn bank_mut(&mut self, id: GlbSliceId) -> Result<&mut GlbBank> {
        self.banks
            .get_mut(id.0 as usize)
            .ok_or_else(|| Error::Config(format!("GLB bank {id} out of range")))
    }

    /// The bank that streams configuration into `slice` under fast-DPR
    /// (paper §2.3: "one GLB bank streams configuration into one
    /// array-slice") — the first bank of the slice's static bank group.
    pub fn dpr_bank_for(&self, slice: ArraySliceId) -> GlbSliceId {
        GlbSliceId(slice.0 * self.banks_per_slice)
    }

    /// The array-slice a bank sits above (static topology).
    pub fn slice_above(&self, bank: GlbSliceId) -> ArraySliceId {
        ArraySliceId(bank.0 / self.banks_per_slice)
    }

    /// Total free bytes across all banks.
    pub fn total_free(&self) -> u64 {
        self.banks.iter().map(|b| b.free_bytes()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn glb() -> GlobalBuffer {
        GlobalBuffer::new(&ArchConfig::default())
    }

    #[test]
    fn paper_bank_count_and_capacity() {
        let g = glb();
        assert_eq!(g.len(), 32);
        assert_eq!(g.bank(GlbSliceId(0)).unwrap().capacity, 128 * 1024);
        assert!(g.bank(GlbSliceId(32)).is_err());
    }

    #[test]
    fn bank_alloc_and_overflow() {
        let mut b = GlbBank::new(1000);
        b.alloc_data(600).unwrap();
        b.alloc_bitstream(300).unwrap();
        assert_eq!(b.free_bytes(), 100);
        assert!(b.alloc_data(200).is_err());
        b.free_data(600);
        b.free_bitstream(300);
        assert_eq!(b.free_bytes(), 1000);
    }

    #[test]
    fn dpr_bank_topology() {
        let g = glb();
        // 32 banks / 8 slices = 4 banks per slice; DPR bank is the first.
        assert_eq!(g.dpr_bank_for(ArraySliceId(0)), GlbSliceId(0));
        assert_eq!(g.dpr_bank_for(ArraySliceId(1)), GlbSliceId(4));
        assert_eq!(g.dpr_bank_for(ArraySliceId(7)), GlbSliceId(28));
        assert_eq!(g.slice_above(GlbSliceId(5)), ArraySliceId(1));
        assert_eq!(g.slice_above(GlbSliceId(31)), ArraySliceId(7));
    }

    #[test]
    fn total_free_accounting() {
        let mut g = glb();
        let total = g.total_free();
        g.bank_mut(GlbSliceId(3)).unwrap().alloc_data(1024).unwrap();
        assert_eq!(g.total_free(), total - 1024);
    }
}
