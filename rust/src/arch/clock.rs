//! Clock domains and cycle-count conversion.
//!
//! The CGRA has two clocks the mechanisms care about: the core clock
//! (tile array, GLB streaming, fast-DPR — paper quotes throughputs at
//! 500 MHz) and the AXI configuration-bus clock (baseline DPR).  Every
//! latency in the simulator is expressed in *core* cycles; this module
//! centralizes the conversions (previously inlined in the DPR engines)
//! and provides the cycle⇄wall-time helpers metrics/reporting use.

use crate::config::ArchConfig;

/// A clock domain with an integer MHz frequency.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Clock {
    /// Frequency in MHz.
    pub mhz: u32,
}

impl Clock {
    /// New domain; frequency must be positive.
    pub fn new(mhz: u32) -> Clock {
        assert!(mhz > 0, "zero-frequency clock");
        Clock { mhz }
    }

    /// Cycles → seconds.
    pub fn to_secs(&self, cycles: u64) -> f64 {
        cycles as f64 / (self.mhz as f64 * 1e6)
    }

    /// Cycles → milliseconds.
    pub fn to_ms(&self, cycles: u64) -> f64 {
        self.to_secs(cycles) * 1e3
    }

    /// Cycles → microseconds.
    pub fn to_us(&self, cycles: u64) -> f64 {
        self.to_secs(cycles) * 1e6
    }

    /// Seconds → cycles (rounded up: a partial cycle still occupies one).
    pub fn from_secs(&self, secs: f64) -> u64 {
        debug_assert!(secs >= 0.0);
        (secs * self.mhz as f64 * 1e6).ceil() as u64
    }

    /// Milliseconds → cycles.
    pub fn from_ms(&self, ms: f64) -> u64 {
        self.from_secs(ms / 1e3)
    }

    /// Convert a cycle count from this domain into `other`'s cycles,
    /// rounding up (crossing domains can only add latency).
    pub fn convert_to(&self, cycles: u64, other: &Clock) -> u64 {
        // ceil(cycles * other.mhz / self.mhz) in integer arithmetic
        let num = cycles as u128 * other.mhz as u128;
        num.div_ceil(self.mhz as u128) as u64
    }
}

/// The CGRA's two clock domains.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ClockTree {
    /// Tile array / GLB / fast-DPR domain.
    pub core: Clock,
    /// AXI4-Lite configuration bus domain.
    pub axi: Clock,
}

impl ClockTree {
    /// Build from architecture parameters.
    pub fn new(arch: &ArchConfig) -> ClockTree {
        ClockTree {
            core: Clock::new(arch.core_clock_mhz),
            axi: Clock::new(arch.axi_clock_mhz),
        }
    }

    /// Express AXI-domain cycles in core cycles (the simulator's unit).
    pub fn axi_to_core(&self, axi_cycles: u64) -> u64 {
        self.axi.convert_to(axi_cycles, &self.core)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cycle_time_round_trip() {
        let c = Clock::new(500);
        assert_eq!(c.to_ms(500_000), 1.0);
        assert_eq!(c.from_ms(1.0), 500_000);
        assert_eq!(c.from_secs(0.0), 0);
        assert!((c.to_us(500) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn partial_cycles_round_up() {
        let c = Clock::new(500);
        assert_eq!(c.from_secs(1e-9), 1); // 0.5 cycles → 1
    }

    #[test]
    fn domain_conversion_matches_dpr_math() {
        // 100 MHz AXI → 500 MHz core: 1 bus cycle = 5 core cycles.
        let t = ClockTree::new(&ArchConfig::default());
        assert_eq!(t.axi_to_core(1), 5);
        assert_eq!(t.axi_to_core(79_872), 399_360);
        // rounding: 3 core cycles at 500 → 1 axi cycle (ceil of 0.6)
        assert_eq!(t.core.convert_to(3, &t.axi), 1);
    }

    #[test]
    #[should_panic]
    fn zero_frequency_rejected() {
        Clock::new(0);
    }
}
