//! Paper-style report tables: fixed-width text output for benches.

use std::fmt::Write as _;

/// Normalize `value` against `baseline` (paper figures plot ratios).
/// Returns 0 when the baseline is 0.
pub fn normalize(value: f64, baseline: f64) -> f64 {
    if baseline == 0.0 {
        0.0
    } else {
        value / baseline
    }
}

/// A fixed-width text table (header + rows), printed by bench targets.
#[derive(Clone, Debug)]
pub struct Table {
    title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// New table with column headers.
    pub fn new(title: impl Into<String>, headers: &[&str]) -> Self {
        Table {
            title: title.into(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row (must match the header arity).
    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    /// Convenience: row from displayable items.
    pub fn row_display(&mut self, cells: &[&dyn std::fmt::Display]) {
        let cells: Vec<String> = cells.iter().map(|c| c.to_string()).collect();
        self.row(&cells);
    }

    /// Number of data rows.
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    /// Whether the table has no rows.
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Render with padded columns.
    pub fn render(&self) -> String {
        let ncols = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "== {} ==", self.title);
        let line = |cells: &[String], widths: &[usize]| -> String {
            let mut s = String::new();
            for i in 0..ncols {
                let _ = write!(s, "{:<width$}", cells[i], width = widths[i] + 2);
            }
            s.trim_end().to_string()
        };
        let _ = writeln!(out, "{}", line(&self.headers, &widths));
        let _ = writeln!(out, "{}", "-".repeat(widths.iter().sum::<usize>() + 2 * (ncols - 1)));
        for row in &self.rows {
            let _ = writeln!(out, "{}", line(row, &widths));
        }
        out
    }
}

/// Format a ratio like the paper quotes them ("1.24x", "0.77x").
pub fn ratio(v: f64) -> String {
    format!("{v:.2}x")
}

/// Format a percentage ("23.4%").
pub fn percent(v: f64) -> String {
    format!("{:.1}%", v * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn normalize_handles_zero_baseline() {
        assert_eq!(normalize(5.0, 0.0), 0.0);
        assert!((normalize(5.0, 4.0) - 1.25).abs() < 1e-12);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("demo", &["name", "value"]);
        t.row(&["a".into(), "1.00".into()]);
        t.row(&["long-name".into(), "2".into()]);
        let r = t.render();
        assert!(r.contains("== demo =="));
        assert!(r.contains("long-name"));
        assert_eq!(t.len(), 2);
        // every data line aligns the second column
        let lines: Vec<&str> = r.lines().collect();
        let col = lines[1].find("value").unwrap();
        assert_eq!(lines[3].find("1.00").unwrap(), col);
    }

    #[test]
    #[should_panic]
    fn arity_mismatch_panics() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(&["only-one".into()]);
    }

    #[test]
    fn formatting_helpers() {
        assert_eq!(ratio(1.2345), "1.23x");
        assert_eq!(percent(0.288), "28.8%");
    }
}
