//! Machine-readable result export (CSV + JSON lines).
//!
//! Bench targets print human tables; experiment pipelines want files.
//! `cgra-mte simulate-* --export out.csv` and the examples use these to
//! dump per-request / per-frame records for external plotting.

use std::fmt::Write as _;
use std::path::Path;

use crate::error::{Error, Result};
use crate::metrics::{FragmentationGauge, LatencyBreakdown, NtatTracker};
use crate::tasks::AppId;

/// Escape one CSV field (RFC 4180 quoting).
pub fn csv_field(s: &str) -> String {
    if s.contains([',', '"', '\n']) {
        format!("\"{}\"", s.replace('"', "\"\""))
    } else {
        s.to_string()
    }
}

/// Serialize rows to CSV text.
pub fn to_csv(headers: &[&str], rows: &[Vec<String>]) -> String {
    let mut out = String::new();
    let _ = writeln!(out, "{}", headers.iter().map(|h| csv_field(h)).collect::<Vec<_>>().join(","));
    for row in rows {
        debug_assert_eq!(row.len(), headers.len());
        let _ = writeln!(out, "{}", row.iter().map(|c| csv_field(c)).collect::<Vec<_>>().join(","));
    }
    out
}

/// Per-request NTAT records as CSV (`app,arrival,completion,exec,tat,ntat`).
pub fn ntat_csv(tracker: &NtatTracker) -> String {
    let rows: Vec<Vec<String>> = tracker
        .records()
        .iter()
        .map(|r| {
            vec![
                r.app.name().to_string(),
                r.arrival.to_string(),
                r.completion.to_string(),
                r.exec_cycles.to_string(),
                r.tat().to_string(),
                format!("{:.6}", r.ntat()),
            ]
        })
        .collect();
    to_csv(&["app", "arrival_cycle", "completion_cycle", "exec_cycles", "tat_cycles", "ntat"], &rows)
}

/// Per-app NTAT summary as one JSON object per line.
pub fn ntat_jsonl(tracker: &NtatTracker) -> String {
    let mut out = String::new();
    let means = tracker.mean_ntat();
    for app in AppId::ALL {
        if let Some(mean) = means.get(&app) {
            let mut s = tracker.summary(app);
            let _ = writeln!(
                out,
                r#"{{"app":"{}","requests":{},"mean_ntat":{:.6},"p95_ntat":{:.6},"max_ntat":{:.6}}}"#,
                app.name(),
                tracker.count(app),
                mean,
                s.percentile(95.0),
                s.max(),
            );
        }
    }
    out
}

/// One-line JSON rendering of a fragmentation gauge — the machine-
/// readable companion to the human `STATS frag_glb=…` wire fields, for
/// experiment pipelines that scrape gauges into files (same pattern as
/// [`ntat_jsonl`]).
pub fn fragmentation_json(g: &FragmentationGauge) -> String {
    format!(
        r#"{{"glb_frag":{:.6},"array_frag":{:.6},"glb_free":{},"array_free":{},"glb_largest_free_run":{},"array_largest_free_run":{},"glb_unallocatable":{:.6},"array_unallocatable":{:.6}}}"#,
        g.glb_frag,
        g.array_frag,
        g.glb_free,
        g.array_free,
        g.glb_largest_free_run,
        g.array_largest_free_run,
        g.glb_unallocatable,
        g.array_unallocatable,
    )
}

/// One-line JSON rendering of a fabric pool's per-shard state — the
/// machine-readable companion to the `STATS SHARDS` wire lines, built
/// from [`crate::fabric::FabricPool::snapshots`].  `placement` names
/// the active routing policy
/// ([`crate::config::PlacementPolicyKind::name`]) so operators can tell
/// which policy a shard pool is actually running.
pub fn pool_json(placement: &str, shards: &[crate::fabric::ShardSnapshot]) -> String {
    let per: Vec<String> = shards
        .iter()
        .map(|s| {
            format!(
                r#"{{"shard":{},"open_requests":{},"running":{},"launches":{},"glb_util":{:.6},"array_util":{:.6},"glb_frag":{:.6},"array_frag":{:.6},"migrations":{},"energy_j":{:.6},"power_w":{:.6}}}"#,
                s.shard,
                s.open_requests,
                s.running,
                s.launches,
                s.glb_utilization,
                s.array_utilization,
                s.gauge.glb_frag,
                s.gauge.array_frag,
                s.migrations,
                s.energy_j,
                s.power_w,
            )
        })
        .collect();
    format!(
        r#"{{"shards":{},"placement":"{}","per_shard":[{}]}}"#,
        shards.len(),
        placement,
        per.join(",")
    )
}

/// One-line JSON rendering of an [`crate::energy::EnergyReport`] — the
/// machine-readable companion to `STATS ENERGY`, written by the energy
/// ablation bench and scraped by experiment pipelines.  Per-component
/// joules are emitted alongside the total so conservation is checkable
/// from the export alone.
pub fn energy_json(r: &crate::energy::EnergyReport) -> String {
    let per_task: Vec<String> = r
        .per_task
        .iter()
        .map(|(task, j)| format!(r#""{task}":{j:.9}"#))
        .collect();
    let per_tenant: Vec<String> = r.per_tenant.iter().map(|j| format!("{j:.9}")).collect();
    format!(
        r#"{{"total_j":{:.9},"pe_j":{:.9},"mem_j":{:.9},"glb_j":{:.9},"idle_j":{:.9},"gated_j":{:.9},"static_j":{:.9},"dpr_j":{:.9},"migration_j":{:.9},"wake_j":{:.9},"horizon_cycles":{},"mean_watts":{:.6},"peak_window_watts":{:.6},"throttled":{},"wakes":{},"per_tenant":[{}],"per_task":{{{}}}}}"#,
        r.total_j,
        r.pe_j,
        r.mem_j,
        r.glb_j,
        r.idle_j,
        r.gated_j,
        r.static_j,
        r.dpr_j,
        r.migration_j,
        r.wake_j,
        r.horizon_cycles,
        r.mean_watts,
        r.peak_window_watts,
        r.throttled,
        r.wakes,
        per_tenant.join(","),
        per_task.join(","),
    )
}

/// One-line JSON rendering of a [`crate::qos::QosReport`] — the
/// machine-readable companion to `STATS QOS`, written by the QoS
/// ablation bench and scraped by experiment pipelines.  Latencies are
/// in cycles (the report is clock-agnostic); `miss_rate` is over
/// deadlined requests only.
pub fn qos_json(r: &crate::qos::QosReport) -> String {
    let per_class: Vec<String> = r
        .per_class
        .iter()
        .map(|c| {
            format!(
                r#"{{"class":"{}","completed":{},"deadlined":{},"missed":{},"miss_rate":{:.6},"p50_latency":{:.3},"p95_latency":{:.3},"p99_latency":{:.3},"mean_slack":{:.3},"min_slack":{:.3}}}"#,
                c.class.name(),
                c.completed,
                c.deadlined,
                c.missed,
                c.miss_rate(),
                c.p50_latency,
                c.p95_latency,
                c.p99_latency,
                c.mean_slack,
                c.min_slack,
            )
        })
        .collect();
    format!(
        r#"{{"preemptions":{},"victims_evicted":{},"victims_resumed":{},"preempt_cycles":{},"per_class":[{}]}}"#,
        r.preemptions,
        r.victims_evicted,
        r.victims_resumed,
        r.preempt_cycles,
        per_class.join(","),
    )
}

/// One-line JSON rendering of a [`crate::noc::NocReport`] — the
/// machine-readable companion to `STATS NOC`, written by the NoC
/// ablation bench and scraped by experiment pipelines.  Slowdowns are
/// multiplicative factors (1.0 = an uncontended corridor); cycle
/// counters are in core cycles.
pub fn noc_json(r: &crate::noc::NocReport) -> String {
    format!(
        r#"{{"streams_placed":{},"contended_launches":{},"contention_cycles":{},"stream_in_cycles":{},"affinity_hits":{},"mean_slowdown":{:.6},"peak_slowdown":{:.6},"corridors":{},"capacity":{}}}"#,
        r.streams_placed,
        r.contended_launches,
        r.contention_cycles,
        r.stream_in_cycles,
        r.affinity_hits,
        r.mean_slowdown,
        r.peak_slowdown,
        r.corridors,
        r.capacity,
    )
}

/// Frame latency breakdown as CSV (`frame,reconfig,wait_exec,total`).
pub fn latency_csv(breakdown: &LatencyBreakdown) -> String {
    let rows: Vec<Vec<String>> = breakdown
        .frames()
        .iter()
        .enumerate()
        .map(|(i, f)| {
            vec![
                i.to_string(),
                f.reconfig_cycles.to_string(),
                f.wait_exec_cycles.to_string(),
                f.total().to_string(),
            ]
        })
        .collect();
    to_csv(&["frame", "reconfig_cycles", "wait_exec_cycles", "total_cycles"], &rows)
}

/// Write text to a file with contextual errors.
pub fn write_file(path: impl AsRef<Path>, text: &str) -> Result<()> {
    let path = path.as_ref();
    std::fs::write(path, text).map_err(|e| Error::io(path.display().to_string(), e))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::NtatRecord;

    #[test]
    fn csv_escaping() {
        assert_eq!(csv_field("plain"), "plain");
        assert_eq!(csv_field("a,b"), "\"a,b\"");
        assert_eq!(csv_field("say \"hi\""), "\"say \"\"hi\"\"\"");
    }

    #[test]
    fn csv_round_trip_shape() {
        let text = to_csv(&["a", "b"], &[vec!["1".into(), "x,y".into()]]);
        let mut lines = text.lines();
        assert_eq!(lines.next(), Some("a,b"));
        assert_eq!(lines.next(), Some("1,\"x,y\""));
        assert_eq!(lines.next(), None);
    }

    #[test]
    fn ntat_exports() {
        let mut t = NtatTracker::new();
        t.record(NtatRecord { app: AppId::Camera, arrival: 0, completion: 200, exec_cycles: 100 });
        t.record(NtatRecord { app: AppId::Harris, arrival: 50, completion: 150, exec_cycles: 100 });
        let csv = ntat_csv(&t);
        assert_eq!(csv.lines().count(), 3);
        assert!(csv.contains("Camera pipeline,0,200,100,200,2.000000"));
        let jsonl = ntat_jsonl(&t);
        assert_eq!(jsonl.lines().count(), 2);
        // each line parses as JSON with our own parser
        for line in jsonl.lines() {
            let v = crate::util::json::Json::parse(line).unwrap();
            assert!(v.get("app").is_some());
            assert!(v.req_f64("mean_ntat").unwrap() >= 1.0);
        }
    }

    #[test]
    fn latency_export() {
        use crate::metrics::FrameLatency;
        let mut b = LatencyBreakdown::new();
        b.record(FrameLatency { reconfig_cycles: 5, wait_exec_cycles: 95 });
        let csv = latency_csv(&b);
        assert!(csv.contains("0,5,95,100"), "{csv}");
    }

    #[test]
    fn write_file_errors_on_bad_path() {
        assert!(write_file("/nonexistent-dir/x.csv", "x").is_err());
    }

    #[test]
    fn pool_json_parses_per_shard() {
        use crate::config::{presets, PlacementPolicyKind};
        use crate::dpr::DprMode;
        use crate::fabric::FabricPool;
        use crate::tasks::TaskLibrary;

        let cfg = presets::pool_scenario(2, PlacementPolicyKind::LeastLoaded);
        let pool = FabricPool::new(&cfg, TaskLibrary::table1(), DprMode::Fast).unwrap();
        let line = pool_json(pool.placement().name(), &pool.snapshots());
        let v = crate::util::json::Json::parse(&line).unwrap();
        assert_eq!(v.req_f64("shards").unwrap(), 2.0);
        assert_eq!(
            v.get("placement").and_then(|p| p.as_str()),
            Some("least-loaded"),
            "operators must see the active placement policy"
        );
        let per = v.get("per_shard").unwrap().items();
        assert_eq!(per.len(), 2);
        assert_eq!(per[0].req_f64("shard").unwrap(), 0.0);
        assert_eq!(per[1].req_f64("shard").unwrap(), 1.0);
        assert_eq!(per[0].req_f64("running").unwrap(), 0.0);
        assert_eq!(per[0].req_f64("glb_frag").unwrap(), 0.0);
        assert_eq!(per[0].req_f64("energy_j").unwrap(), 0.0, "accounting off by default");
    }

    #[test]
    fn energy_json_parses_and_conserves() {
        use crate::config::{presets, RegionPolicyKind, WorkloadConfig};
        use crate::sim::run_cloud;

        let mut cfg = presets::cloud_scenario(RegionPolicyKind::FlexibleShape);
        cfg.energy.enabled = true;
        if let WorkloadConfig::Cloud(ref mut c) = cfg.workload {
            c.duration_ms = 200.0;
        }
        let r = run_cloud(&cfg).unwrap();
        let energy = r.energy.expect("accounting enabled");
        let line = energy_json(&energy);
        let v = crate::util::json::Json::parse(&line).unwrap();
        let total = v.req_f64("total_j").unwrap();
        assert!(total > 0.0);
        let sum = ["pe_j", "mem_j", "glb_j", "idle_j", "gated_j", "static_j", "dpr_j",
                   "migration_j", "wake_j"]
            .iter()
            .map(|k| v.req_f64(k).unwrap())
            .sum::<f64>();
        assert!((sum - total).abs() <= 1e-6 * total, "{sum} vs {total}");
        assert_eq!(v.get("per_tenant").unwrap().items().len(), 4);
        assert!(v.req_f64("mean_watts").unwrap() > 0.0);
    }

    #[test]
    fn qos_json_parses_and_counts_classes() {
        use crate::qos::{QosStats, SloRecord, SloTracker};

        let mut t = SloTracker::new();
        t.record(SloRecord {
            class: crate::config::QosClass::Critical,
            arrival: 0,
            completion: 120,
            deadline: Some(100),
        });
        t.record(SloRecord {
            class: crate::config::QosClass::BestEffort,
            arrival: 0,
            completion: 900,
            deadline: None,
        });
        let report = t.report(QosStats { preemptions: 1, victims_evicted: 1, ..Default::default() });
        let line = qos_json(&report);
        let v = crate::util::json::Json::parse(&line).unwrap();
        assert_eq!(v.req_f64("preemptions").unwrap(), 1.0);
        let per = v.get("per_class").unwrap().items();
        assert_eq!(per.len(), 3);
        let crit = per
            .iter()
            .find(|c| c.get("class").and_then(|s| s.as_str()) == Some("critical"))
            .expect("critical row");
        assert_eq!(crit.req_f64("missed").unwrap(), 1.0);
        assert_eq!(crit.req_f64("miss_rate").unwrap(), 1.0);
        assert!(crit.req_f64("mean_slack").unwrap() < 0.0);
    }

    #[test]
    fn noc_json_parses() {
        let r = crate::noc::NocReport {
            streams_placed: 12,
            contended_launches: 3,
            contention_cycles: 4_500,
            stream_in_cycles: 86_400,
            affinity_hits: 7,
            mean_slowdown: 1.125,
            peak_slowdown: 1.75,
            corridors: 8,
            capacity: 20,
        };
        let line = noc_json(&r);
        let v = crate::util::json::Json::parse(&line).unwrap();
        assert_eq!(v.req_f64("streams_placed").unwrap(), 12.0);
        assert_eq!(v.req_f64("contended_launches").unwrap(), 3.0);
        assert_eq!(v.req_f64("stream_in_cycles").unwrap(), 86_400.0);
        assert_eq!(v.req_f64("mean_slowdown").unwrap(), 1.125);
        assert_eq!(v.req_f64("peak_slowdown").unwrap(), 1.75);
        assert_eq!(v.req_f64("corridors").unwrap(), 8.0);
        assert_eq!(v.req_f64("capacity").unwrap(), 20.0);
    }

    #[test]
    fn fragmentation_json_parses() {
        let g = FragmentationGauge {
            glb_frag: 0.5,
            array_frag: 0.25,
            glb_free: 16,
            array_free: 4,
            glb_largest_free_run: 8,
            array_largest_free_run: 3,
            glb_unallocatable: 0.25,
            array_unallocatable: 0.125,
        };
        let line = fragmentation_json(&g);
        let v = crate::util::json::Json::parse(&line).unwrap();
        assert_eq!(v.req_f64("glb_frag").unwrap(), 0.5);
        assert_eq!(v.req_f64("array_frag").unwrap(), 0.25);
        assert_eq!(v.req_f64("glb_free").unwrap(), 16.0);
        assert_eq!(v.req_f64("array_largest_free_run").unwrap(), 3.0);
    }
}
