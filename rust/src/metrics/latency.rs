//! Frame-latency breakdown for the autonomous scenario (paper Fig. 5).
//!
//! Figure 5 splits each bar into reconfiguration time (red) and
//! wait + execution time (blue); we track both per frame and report
//! averages and the reconfiguration share.

use crate::util::stats::Summary;

/// Latency of one frame's task set.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct FrameLatency {
    /// Cycles spent reconfiguring (sum over the frame's launches).
    pub reconfig_cycles: u64,
    /// Wait + execution cycles: frame completion − frame start −
    /// reconfig.
    pub wait_exec_cycles: u64,
}

impl FrameLatency {
    /// Total frame latency.
    pub fn total(&self) -> u64 {
        self.reconfig_cycles + self.wait_exec_cycles
    }
}

/// Accumulates frame latencies.
#[derive(Clone, Debug, Default)]
pub struct LatencyBreakdown {
    frames: Vec<FrameLatency>,
}

impl LatencyBreakdown {
    /// Empty breakdown.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one frame.
    pub fn record(&mut self, frame: FrameLatency) {
        self.frames.push(frame);
    }

    /// Frame count.
    pub fn len(&self) -> usize {
        self.frames.len()
    }

    /// Whether no frames were recorded.
    pub fn is_empty(&self) -> bool {
        self.frames.is_empty()
    }

    /// Mean total latency in cycles (Fig. 5 bar height).
    pub fn mean_total(&self) -> f64 {
        Summary::from_iter(self.frames.iter().map(|f| f.total() as f64)).mean()
    }

    /// Mean reconfiguration cycles (red portion).
    pub fn mean_reconfig(&self) -> f64 {
        Summary::from_iter(self.frames.iter().map(|f| f.reconfig_cycles as f64)).mean()
    }

    /// Mean wait+exec cycles (blue portion).
    pub fn mean_wait_exec(&self) -> f64 {
        Summary::from_iter(self.frames.iter().map(|f| f.wait_exec_cycles as f64)).mean()
    }

    /// Reconfiguration share of total latency (paper: 14.4 % baseline,
    /// <5 % with fast-DPR).
    pub fn reconfig_share(&self) -> f64 {
        let total = self.mean_total();
        if total == 0.0 {
            0.0
        } else {
            self.mean_reconfig() / total
        }
    }

    /// All recorded frames, in order.
    pub fn frames(&self) -> &[FrameLatency] {
        &self.frames
    }

    /// Percentile of total frame latency (the SLO tracker's p50/p95/p99
    /// companions to the Fig. 5 means).
    pub fn percentile_total(&self, p: f64) -> f64 {
        Summary::from_iter(self.frames.iter().map(|f| f.total() as f64)).percentile(p)
    }

    /// p50 (median) of total frame latency.
    pub fn p50_total(&self) -> f64 {
        self.percentile_total(50.0)
    }

    /// p95 of total frame latency.
    pub fn p95_total(&self) -> f64 {
        self.percentile_total(95.0)
    }

    /// p99 of total frame latency.
    pub fn p99_total(&self) -> f64 {
        self.percentile_total(99.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn means_and_share() {
        let mut b = LatencyBreakdown::new();
        b.record(FrameLatency { reconfig_cycles: 10, wait_exec_cycles: 90 });
        b.record(FrameLatency { reconfig_cycles: 30, wait_exec_cycles: 70 });
        assert_eq!(b.len(), 2);
        assert!((b.mean_total() - 100.0).abs() < 1e-12);
        assert!((b.mean_reconfig() - 20.0).abs() < 1e-12);
        assert!((b.reconfig_share() - 0.2).abs() < 1e-12);
    }

    #[test]
    fn empty_breakdown_is_safe() {
        let b = LatencyBreakdown::new();
        assert!(b.is_empty());
        assert_eq!(b.mean_total(), 0.0);
        assert_eq!(b.reconfig_share(), 0.0);
    }

    #[test]
    fn p99_tracks_tail() {
        let mut b = LatencyBreakdown::new();
        for _ in 0..99 {
            b.record(FrameLatency { reconfig_cycles: 0, wait_exec_cycles: 100 });
        }
        b.record(FrameLatency { reconfig_cycles: 0, wait_exec_cycles: 1000 });
        assert!(b.p99_total() > 100.0);
    }

    #[test]
    fn single_sample_pins_every_percentile() {
        let mut b = LatencyBreakdown::new();
        b.record(FrameLatency { reconfig_cycles: 40, wait_exec_cycles: 160 });
        // with one sample there is nothing to interpolate between: every
        // percentile reads that sample
        for p in [0.0, 1.0, 50.0, 95.0, 99.0, 100.0] {
            assert_eq!(b.percentile_total(p), 200.0, "p{p}");
        }
        assert_eq!(b.mean_total(), 200.0);
    }

    #[test]
    fn duplicate_heavy_input_keeps_percentiles_at_the_mode() {
        let mut b = LatencyBreakdown::new();
        for _ in 0..999 {
            b.record(FrameLatency { reconfig_cycles: 0, wait_exec_cycles: 500 });
        }
        b.record(FrameLatency { reconfig_cycles: 0, wait_exec_cycles: 700 });
        // one outlier in a thousand duplicates moves nothing below p100:
        // the interpolation indices for p50/p95/p99 all land inside the
        // run of 500s
        assert_eq!(b.p50_total(), 500.0);
        assert_eq!(b.p95_total(), 500.0);
        assert_eq!(b.p99_total(), 500.0);
        assert_eq!(b.percentile_total(100.0), 700.0);
    }

    #[test]
    fn percentile_family_is_monotone() {
        let mut b = LatencyBreakdown::new();
        for i in 1..=100u64 {
            b.record(FrameLatency { reconfig_cycles: 0, wait_exec_cycles: i * 10 });
        }
        assert!((b.p50_total() - 505.0).abs() < 1e-9);
        assert!(b.p50_total() <= b.p95_total());
        assert!(b.p95_total() <= b.p99_total());
        assert_eq!(b.percentile_total(100.0), 1000.0);
        // empty breakdown reads zeros, not a panic
        let empty = LatencyBreakdown::new();
        assert_eq!(empty.p50_total(), 0.0);
        assert_eq!(empty.p95_total(), 0.0);
    }
}
