//! Per-tenant serving counters for the live coordinator.
//!
//! The worker-pool server ([`crate::coordinator::Server`]) is crossed by
//! three thread populations — connection handlers, scheduler workers and
//! the leader executor — so its counters are plain atomics: connection
//! threads record admissions/rejections, workers record completions, and
//! `STATS` renders a consistent-enough snapshot without any lock.

use std::sync::atomic::{AtomicU64, Ordering};

/// Point-in-time snapshot of one tenant's (or the aggregate) counters.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TenantSnapshot {
    /// SUBMITs admitted into the tenant's bounded queue.
    pub queued: u64,
    /// SUBMITs refused with `BUSY` (queue full / shutting down).
    pub rejected: u64,
    /// SUBMITs fully served (an `OK` reply was produced).
    pub served: u64,
}

/// Lock-free per-tenant served/queued/rejected counters.
#[derive(Debug)]
pub struct ServeCounters {
    queued: Vec<AtomicU64>,
    rejected: Vec<AtomicU64>,
    served: Vec<AtomicU64>,
    /// Submissions that entered the scheduler but produced no outcome
    /// (batch-level errors) — aggregate, not per-tenant.
    failed: AtomicU64,
}

impl ServeCounters {
    /// Counters for `tenants` tenants.
    pub fn new(tenants: usize) -> ServeCounters {
        let col = |n: usize| (0..n).map(|_| AtomicU64::new(0)).collect::<Vec<_>>();
        ServeCounters {
            queued: col(tenants),
            rejected: col(tenants),
            served: col(tenants),
            failed: AtomicU64::new(0),
        }
    }

    /// Number of tenants tracked.
    pub fn tenants(&self) -> usize {
        self.queued.len()
    }

    /// Record an admission for `tenant` (out-of-range ids are ignored).
    pub fn record_queued(&self, tenant: usize) {
        if let Some(c) = self.queued.get(tenant) {
            c.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Record a `BUSY` rejection for `tenant`.
    pub fn record_rejected(&self, tenant: usize) {
        if let Some(c) = self.rejected.get(tenant) {
            c.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Record a completed request for `tenant`.
    pub fn record_served(&self, tenant: usize) {
        if let Some(c) = self.served.get(tenant) {
            c.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Record a submission lost to a batch-level error.
    pub fn record_failed(&self) {
        self.failed.fetch_add(1, Ordering::Relaxed);
    }

    /// Snapshot of one tenant (zeros when out of range).
    pub fn tenant(&self, tenant: usize) -> TenantSnapshot {
        let read = |v: &[AtomicU64]| v.get(tenant).map_or(0, |c| c.load(Ordering::Relaxed));
        TenantSnapshot {
            queued: read(&self.queued),
            rejected: read(&self.rejected),
            served: read(&self.served),
        }
    }

    /// Aggregate snapshot across all tenants.
    pub fn totals(&self) -> TenantSnapshot {
        let sum = |v: &[AtomicU64]| v.iter().map(|c| c.load(Ordering::Relaxed)).sum();
        TenantSnapshot {
            queued: sum(&self.queued),
            rejected: sum(&self.rejected),
            served: sum(&self.served),
        }
    }

    /// Submissions lost to batch-level errors.
    pub fn failed(&self) -> u64 {
        self.failed.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn per_tenant_and_totals() {
        let c = ServeCounters::new(4);
        assert_eq!(c.tenants(), 4);
        c.record_queued(0);
        c.record_queued(0);
        c.record_served(0);
        c.record_queued(2);
        c.record_rejected(2);
        c.record_failed();
        assert_eq!(c.tenant(0), TenantSnapshot { queued: 2, rejected: 0, served: 1 });
        assert_eq!(c.tenant(2), TenantSnapshot { queued: 1, rejected: 1, served: 0 });
        assert_eq!(c.tenant(3), TenantSnapshot::default());
        assert_eq!(c.totals(), TenantSnapshot { queued: 3, rejected: 1, served: 1 });
        assert_eq!(c.failed(), 1);
    }

    #[test]
    fn out_of_range_tenants_are_ignored() {
        let c = ServeCounters::new(2);
        c.record_queued(7);
        c.record_rejected(7);
        c.record_served(7);
        assert_eq!(c.totals(), TenantSnapshot::default());
        assert_eq!(c.tenant(7), TenantSnapshot::default());
    }

    #[test]
    fn concurrent_updates_are_not_lost() {
        let c = std::sync::Arc::new(ServeCounters::new(1));
        let threads: Vec<_> = (0..4)
            .map(|_| {
                let c = c.clone();
                std::thread::spawn(move || {
                    for _ in 0..1000 {
                        c.record_queued(0);
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        assert_eq!(c.tenant(0).queued, 4000);
    }
}
