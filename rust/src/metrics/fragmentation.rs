//! External-fragmentation gauge and its time-weighted tracker.
//!
//! Fragmentation is the failure mode live migration
//! ([`crate::migration`]) exists to repair: free slices that cannot be
//! allocated because no contiguous run is long enough.  The gauge is a
//! point-in-time reading of both slice maps; the tracker integrates the
//! reading across a simulation the same way
//! [`crate::metrics::UtilizationTracker`] integrates occupancy.

use crate::regions::RegionManager;

/// Point-in-time fragmentation reading for both slice classes.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct FragmentationGauge {
    /// GLB-slice external fragmentation: `1 − largest-free-run ⁄ free`.
    pub glb_frag: f64,
    /// Array-slice external fragmentation.
    pub array_frag: f64,
    /// Free GLB slices.
    pub glb_free: u32,
    /// Free array slices.
    pub array_free: u32,
    /// Longest contiguous free GLB run (the largest demand placeable).
    pub glb_largest_free_run: u32,
    /// Longest contiguous free array run.
    pub array_largest_free_run: u32,
    /// Free-but-unallocatable GLB fraction: slices that are free yet
    /// outside the largest free run, over the whole map.
    pub glb_unallocatable: f64,
    /// Free-but-unallocatable array fraction.
    pub array_unallocatable: f64,
}

impl FragmentationGauge {
    /// Read the gauge off a region manager's slice maps.
    pub fn read(mgr: &RegionManager) -> FragmentationGauge {
        let (glb_frag, array_frag) = mgr.fragmentation();
        let glb = mgr.glb_map();
        let arr = mgr.array_map();
        let g_run = glb.longest_free_run().len;
        let a_run = arr.longest_free_run().len;
        let g_free = glb.free_count();
        let a_free = arr.free_count();
        FragmentationGauge {
            glb_frag,
            array_frag,
            glb_free: g_free,
            array_free: a_free,
            glb_largest_free_run: g_run,
            array_largest_free_run: a_run,
            glb_unallocatable: (g_free - g_run) as f64 / glb.len().max(1) as f64,
            array_unallocatable: (a_free - a_run) as f64 / arr.len().max(1) as f64,
        }
    }
}

/// Time-weighted mean fragmentation over a simulation.
///
/// Sampled at event boundaries (fragmentation is piecewise-constant
/// between events), mirroring [`crate::metrics::UtilizationTracker`].
#[derive(Clone, Debug, Default)]
pub struct FragmentationTracker {
    last_cycle: u64,
    cur: (f64, f64),
    integral: (f64, f64),
}

impl FragmentationTracker {
    /// Start tracking at cycle 0 on a defragmented machine.
    pub fn new() -> FragmentationTracker {
        FragmentationTracker::default()
    }

    /// Advance to `now`, recording the `(glb, array)` fragmentation that
    /// held since the previous sample.
    pub fn sample(&mut self, now: u64, frag: (f64, f64)) {
        debug_assert!(now >= self.last_cycle, "time went backwards");
        let dt = (now - self.last_cycle) as f64;
        self.integral.0 += self.cur.0 * dt;
        self.integral.1 += self.cur.1 * dt;
        self.cur = frag;
        self.last_cycle = now;
    }

    /// Time-weighted mean `(glb, array)` fragmentation so far.
    pub fn mean(&self) -> (f64, f64) {
        if self.last_cycle == 0 {
            return (0.0, 0.0);
        }
        let t = self.last_cycle as f64;
        (self.integral.0 / t, self.integral.1 / t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::abstraction::SliceDemand;
    use crate::config::{ArchConfig, RegionPolicyKind, SchedulerConfig};

    fn fragmented_mgr() -> RegionManager {
        let sched = SchedulerConfig {
            region_policy: RegionPolicyKind::FlexibleShape,
            ..SchedulerConfig::default()
        };
        let mut m = RegionManager::new(&ArchConfig::default(), &sched);
        let d = SliceDemand::new(8, 2);
        let ids: Vec<_> = (0..3)
            .map(|_| match m.try_allocate(&d) {
                crate::regions::AllocOutcome::Allocated(r) => r.id,
                other => panic!("{other:?}"),
            })
            .collect();
        m.release(ids[1]).unwrap();
        m
    }

    #[test]
    fn gauge_reads_holes() {
        let m = fragmented_mgr();
        let g = FragmentationGauge::read(&m);
        // array: free {2,3} ∪ {6,7} → 4 free, largest run 2
        assert_eq!(g.array_free, 4);
        assert_eq!(g.array_largest_free_run, 2);
        assert!((g.array_frag - 0.5).abs() < 1e-12);
        assert!((g.array_unallocatable - 2.0 / 8.0).abs() < 1e-12);
        // glb: free [8..16) ∪ [24..32) → 16 free, largest run 8
        assert_eq!(g.glb_free, 16);
        assert_eq!(g.glb_largest_free_run, 8);
        assert!((g.glb_frag - 0.5).abs() < 1e-12);
        assert!((g.glb_unallocatable - 8.0 / 32.0).abs() < 1e-12);
    }

    #[test]
    fn gauge_is_zero_on_idle_and_packed_machines() {
        let sched = SchedulerConfig {
            region_policy: RegionPolicyKind::FlexibleShape,
            ..SchedulerConfig::default()
        };
        let mut m = RegionManager::new(&ArchConfig::default(), &sched);
        let g = FragmentationGauge::read(&m);
        assert_eq!((g.glb_frag, g.array_frag), (0.0, 0.0));
        assert_eq!(g.glb_unallocatable, 0.0);
        let _ = m.try_allocate(&SliceDemand::new(8, 2));
        let g2 = FragmentationGauge::read(&m);
        assert_eq!((g2.glb_frag, g2.array_frag), (0.0, 0.0));
    }

    #[test]
    fn tracker_integrates_piecewise() {
        let mut t = FragmentationTracker::new();
        t.sample(0, (0.0, 0.5));
        t.sample(100, (1.0, 0.5)); // (0.0, 0.5) held over [0, 100)
        t.sample(200, (0.0, 0.0)); // (1.0, 0.5) held over [100, 200)
        let (g, a) = t.mean();
        assert!((g - 0.5).abs() < 1e-12, "{g}");
        assert!((a - 0.5).abs() < 1e-12, "{a}");
    }

    #[test]
    fn empty_tracker_is_zero() {
        assert_eq!(FragmentationTracker::new().mean(), (0.0, 0.0));
    }
}
