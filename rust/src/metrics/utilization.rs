//! Time-weighted slice utilization.
//!
//! Sampled at every event boundary: between two events the occupancy is
//! constant, so integrating occupancy × elapsed gives exact utilization —
//! the quantity the paper's mechanisms are designed to raise.

/// Time-weighted utilization integrator for one slice class.
#[derive(Clone, Debug)]
pub struct UtilizationTracker {
    total_slices: u32,
    last_cycle: u64,
    busy_now: u32,
    /// ∫ busy dt in slice·cycles.
    busy_integral: u128,
}

impl UtilizationTracker {
    /// Start tracking at cycle 0 with everything idle.
    pub fn new(total_slices: u32) -> Self {
        UtilizationTracker { total_slices, last_cycle: 0, busy_now: 0, busy_integral: 0 }
    }

    /// Advance to `now` and record the occupancy that held since the last
    /// sample.  `now` must be monotonically non-decreasing.
    pub fn sample(&mut self, now: u64, busy_slices: u32) {
        debug_assert!(now >= self.last_cycle, "time went backwards");
        debug_assert!(busy_slices <= self.total_slices);
        let dt = (now - self.last_cycle) as u128;
        self.busy_integral += dt * self.busy_now as u128;
        self.busy_now = busy_slices;
        self.last_cycle = now;
    }

    /// Mean utilization in `[0,1]` up to the last sample point.
    pub fn mean(&self) -> f64 {
        if self.last_cycle == 0 || self.total_slices == 0 {
            return 0.0;
        }
        self.busy_integral as f64 / (self.last_cycle as f64 * self.total_slices as f64)
    }

    /// Final sampled cycle.
    pub fn horizon(&self) -> u64 {
        self.last_cycle
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant_half_busy() {
        let mut u = UtilizationTracker::new(8);
        u.sample(0, 4);
        u.sample(1000, 4);
        assert!((u.mean() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn piecewise_occupancy_integrates() {
        let mut u = UtilizationTracker::new(4);
        u.sample(0, 0); // idle 0..0
        u.sample(100, 4); // 0 busy until 100, then full
        u.sample(200, 0); // full 100..200
        u.sample(400, 0); // idle 200..400
        // busy integral = 4 * 100 = 400 slice·cycles over 400*4
        assert!((u.mean() - 0.25).abs() < 1e-12);
        assert_eq!(u.horizon(), 400);
    }

    #[test]
    fn zero_time_is_safe() {
        let u = UtilizationTracker::new(8);
        assert_eq!(u.mean(), 0.0);
    }
}
