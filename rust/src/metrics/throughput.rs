//! Per-application throughput (paper Fig. 4b).
//!
//! Two views are tracked:
//!
//! * **service throughput** — work / TAT per request, averaged per app:
//!   the rate a tenant experiences end-to-end (this is what Fig. 4b's
//!   normalized ratios respond to at moderate load), and
//! * **aggregate rate** — total completed work per simulated second:
//!   saturation-sensitive machine goodput.

use std::collections::BTreeMap;

use crate::tasks::AppId;
use crate::util::stats::Summary;

/// Accumulates per-app throughput.
#[derive(Clone, Debug, Default)]
pub struct ThroughputTracker {
    /// (app, work units, tat cycles)
    completed: Vec<(AppId, u64, u64)>,
}

impl ThroughputTracker {
    /// Empty tracker.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record a completed request: total work units and its TAT.
    pub fn record(&mut self, app: AppId, work: u64, tat_cycles: u64) {
        debug_assert!(tat_cycles > 0);
        self.completed.push((app, work, tat_cycles));
    }

    /// Mean service throughput per app (work units / cycle).
    pub fn service_throughput(&self) -> BTreeMap<AppId, f64> {
        let mut by_app: BTreeMap<AppId, Summary> = BTreeMap::new();
        for &(app, work, tat) in &self.completed {
            by_app.entry(app).or_default().add(work as f64 / tat as f64);
        }
        by_app.into_iter().map(|(a, s)| (a, s.mean())).collect()
    }

    /// Aggregate completed work per app over `duration_cycles`
    /// (units/cycle).
    pub fn aggregate_rate(&self, duration_cycles: u64) -> BTreeMap<AppId, f64> {
        debug_assert!(duration_cycles > 0);
        let mut by_app: BTreeMap<AppId, u64> = BTreeMap::new();
        for &(app, work, _) in &self.completed {
            *by_app.entry(app).or_default() += work;
        }
        by_app
            .into_iter()
            .map(|(a, w)| (a, w as f64 / duration_cycles as f64))
            .collect()
    }

    /// Completed request count per app.
    pub fn counts(&self) -> BTreeMap<AppId, usize> {
        let mut by_app: BTreeMap<AppId, usize> = BTreeMap::new();
        for &(app, _, _) in &self.completed {
            *by_app.entry(app).or_default() += 1;
        }
        by_app
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn service_throughput_averages_per_request() {
        let mut t = ThroughputTracker::new();
        t.record(AppId::Camera, 1000, 100); // 10/cyc
        t.record(AppId::Camera, 1000, 500); // 2/cyc
        let s = t.service_throughput();
        assert!((s[&AppId::Camera] - 6.0).abs() < 1e-12);
    }

    #[test]
    fn aggregate_rate_sums_work() {
        let mut t = ThroughputTracker::new();
        t.record(AppId::Harris, 300, 10);
        t.record(AppId::Harris, 700, 10);
        let a = t.aggregate_rate(1000);
        assert!((a[&AppId::Harris] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn counts_by_app() {
        let mut t = ThroughputTracker::new();
        t.record(AppId::ResNet18, 1, 1);
        t.record(AppId::ResNet18, 1, 1);
        t.record(AppId::MobileNet, 1, 1);
        let c = t.counts();
        assert_eq!(c[&AppId::ResNet18], 2);
        assert_eq!(c[&AppId::MobileNet], 1);
    }
}
