//! Evaluation metrics (§3): NTAT, throughput, latency breakdown,
//! utilization, fragmentation, and paper-style report tables.

mod counters;
pub mod export;
mod fragmentation;
mod latency;
mod ntat;
mod report;
mod throughput;
mod utilization;

pub use counters::{ServeCounters, TenantSnapshot};
pub use fragmentation::{FragmentationGauge, FragmentationTracker};
pub use latency::{FrameLatency, LatencyBreakdown};
pub use ntat::{NtatRecord, NtatTracker};
pub use report::{normalize, percent, ratio, Table};
pub use throughput::ThroughputTracker;
pub use utilization::UtilizationTracker;
