//! Normalized Turn-Around Time (paper Eq. 1–2).
//!
//! `TAT = wait_time + execution_time`; `NTAT = TAT / execution_time` —
//! the relative delay a request experiences.  Computed per request and
//! arithmetically averaged per application (§3.1 Metrics).

use std::collections::BTreeMap;

use crate::tasks::AppId;
use crate::util::stats::Summary;

/// Completed-request record.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct NtatRecord {
    /// Application the request belongs to.
    pub app: AppId,
    /// Arrival cycle.
    pub arrival: u64,
    /// Completion cycle (last task of the request).
    pub completion: u64,
    /// Sum of serviced cycles (DPR + execution across the app's tasks).
    pub exec_cycles: u64,
}

impl NtatRecord {
    /// Turn-around time in cycles.
    pub fn tat(&self) -> u64 {
        self.completion - self.arrival
    }

    /// NTAT (≥ 1; exactly 1 means zero waiting).
    pub fn ntat(&self) -> f64 {
        debug_assert!(self.exec_cycles > 0);
        self.tat() as f64 / self.exec_cycles as f64
    }
}

/// Accumulates per-app NTAT summaries.
#[derive(Clone, Debug, Default)]
pub struct NtatTracker {
    records: Vec<NtatRecord>,
}

impl NtatTracker {
    /// Empty tracker.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record a completed request.
    pub fn record(&mut self, rec: NtatRecord) {
        debug_assert!(rec.completion >= rec.arrival, "completion before arrival");
        debug_assert!(rec.exec_cycles > 0, "zero exec time");
        self.records.push(rec);
    }

    /// All records.
    pub fn records(&self) -> &[NtatRecord] {
        &self.records
    }

    /// Completed-request count per app.
    pub fn count(&self, app: AppId) -> usize {
        self.records.iter().filter(|r| r.app == app).count()
    }

    /// Mean NTAT per app (paper's Fig. 4a series).
    pub fn mean_ntat(&self) -> BTreeMap<AppId, f64> {
        let mut by_app: BTreeMap<AppId, Summary> = BTreeMap::new();
        for r in &self.records {
            by_app.entry(r.app).or_default().add(r.ntat());
        }
        by_app.into_iter().map(|(app, s)| (app, s.mean())).collect()
    }

    /// Full NTAT summary for one app.
    pub fn summary(&self, app: AppId) -> Summary {
        Summary::from_iter(self.records.iter().filter(|r| r.app == app).map(|r| r.ntat()))
    }

    /// Overall mean NTAT across all requests.
    pub fn overall_mean(&self) -> f64 {
        Summary::from_iter(self.records.iter().map(|r| r.ntat())).mean()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(app: AppId, arrival: u64, completion: u64, exec: u64) -> NtatRecord {
        NtatRecord { app, arrival, completion, exec_cycles: exec }
    }

    #[test]
    fn ntat_is_one_without_waiting() {
        let r = rec(AppId::Camera, 100, 150, 50);
        assert_eq!(r.tat(), 50);
        assert!((r.ntat() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn ntat_reflects_waiting() {
        let r = rec(AppId::Harris, 0, 300, 100);
        assert!((r.ntat() - 3.0).abs() < 1e-12);
    }

    #[test]
    fn per_app_means_are_separate() {
        let mut t = NtatTracker::new();
        t.record(rec(AppId::Camera, 0, 100, 100)); // ntat 1
        t.record(rec(AppId::Camera, 0, 300, 100)); // ntat 3
        t.record(rec(AppId::Harris, 0, 500, 100)); // ntat 5
        let means = t.mean_ntat();
        assert!((means[&AppId::Camera] - 2.0).abs() < 1e-12);
        assert!((means[&AppId::Harris] - 5.0).abs() < 1e-12);
        assert_eq!(t.count(AppId::Camera), 2);
        assert!((t.overall_mean() - 3.0).abs() < 1e-12);
    }

    #[test]
    fn summary_percentiles_available() {
        let mut t = NtatTracker::new();
        for i in 1..=10 {
            t.record(rec(AppId::MobileNet, 0, i * 100, 100));
        }
        let mut s = t.summary(AppId::MobileNet);
        assert_eq!(s.count(), 10);
        assert!(s.max() >= 9.9);
    }
}
