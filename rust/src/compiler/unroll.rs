//! Unroll transform: the variant generator (§2.2).
//!
//! "Increasing the unroll factor of the same task by four would achieve
//! 4× throughput (256 OPs/cycle) with 288 PE tiles, 33 MEM tiles, and
//! the same GLB memory capacity and bandwidth."
//!
//! Unrolling replicates the compute lanes and the per-lane scratchpads;
//! GLB capacity is shared (weights/activations are read by all copies)
//! and GLB bandwidth stays put because each copy reads a different
//! sub-stream of the same staged data.

use super::dfg::{Dfg, DfgNode};

/// Unroll a task DFG by `factor` (`factor = 1` is the identity).
pub fn unroll(dfg: &Dfg, factor: u32) -> Dfg {
    assert!(factor >= 1, "unroll factor must be >= 1");
    let mut out = dfg.clone();
    if factor == 1 {
        return out;
    }
    out.name = format!("{}@x{}", dfg.name, factor);
    for node in &mut out.nodes {
        match node {
            DfgNode::PeCompute { lanes, .. } => {
                // MACs per invocation are unchanged — they finish
                // `factor`× faster across `factor`× lanes.
                *lanes *= factor;
            }
            DfgNode::MemBuffer { banks, bytes } => {
                // each copy needs its own line buffers, but shared
                // buffering amortizes: replicate banks sub-linearly
                // (empirically ~2x per 4x unroll in Amber mappings).
                let extra = (*banks * (factor - 1)).div_ceil(2);
                *banks += extra;
                *bytes += (*bytes * (factor as u64 - 1)).div_ceil(2);
            }
            DfgNode::GlbBuffer { .. } => {} // shared
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::dfg::resnet_stage_dfg;
    use crate::compiler::mapper::map_dfg;
    use crate::config::ArchConfig;

    #[test]
    fn unroll_by_one_is_identity_modulo_nothing() {
        let d = resnet_stage_dfg(2);
        assert_eq!(unroll(&d, 1), d);
    }

    #[test]
    fn paper_4x_unroll_example() {
        // §2.2's worked example: conv2_x ×4 ⇒ 288 PE tiles, ~33 MEM
        // tiles, same GLB, 6 array-slices, 256 MACs/cycle.
        let arch = ArchConfig::default();
        let base = resnet_stage_dfg(2);
        let un = unroll(&base, 4);
        let v = map_dfg(&un, &arch).unwrap();
        assert_eq!(v.raw.pe_tiles, 320); // 256 lanes + 64 glue (paper: 288)
        assert_eq!(v.demand.array_slices, 7); // ceil(320/48); Table 1 pins 6
        assert_eq!(v.throughput, 256.0);
        assert_eq!(v.raw.glb_bytes, map_dfg(&base, &arch).unwrap().raw.glb_bytes);
    }

    #[test]
    fn glb_capacity_and_bw_shared_across_unroll() {
        let arch = ArchConfig::default();
        let base = map_dfg(&resnet_stage_dfg(3), &arch).unwrap();
        let un = map_dfg(&unroll(&resnet_stage_dfg(3), 4), &arch).unwrap();
        assert_eq!(base.raw.glb_bytes, un.raw.glb_bytes);
        assert_eq!(base.raw.glb_bw_bytes_per_sec, un.raw.glb_bw_bytes_per_sec);
        assert_eq!(base.demand.glb_slices, un.demand.glb_slices);
    }

    #[test]
    fn mem_tiles_grow_sublinearly() {
        let arch = ArchConfig::default();
        let base = map_dfg(&resnet_stage_dfg(2), &arch).unwrap();
        let un = map_dfg(&unroll(&resnet_stage_dfg(2), 4), &arch).unwrap();
        assert!(un.raw.mem_tiles > base.raw.mem_tiles);
        assert!(un.raw.mem_tiles < base.raw.mem_tiles * 4);
    }

    #[test]
    #[should_panic]
    fn zero_factor_panics() {
        unroll(&resnet_stage_dfg(2), 0);
    }
}
