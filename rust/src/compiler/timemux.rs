//! Time-multiplex optimization across a merged region (§2.3).
//!
//! The paper's variably-sized-region example: "a camera pipeline task
//! with 3 pixels/cycle throughput uses four array-slices.  Naively
//! unrolling it by four achieves 12 pixels/cycle using 16 array-slices.
//! However, the compiler can optimize to time-multiplex PE tiles and
//! achieve 12 pixels/cycle with only six array-slices."
//!
//! The optimization works because an unrolled stencil pipeline leaves
//! many PEs idle between phases; scheduling several logical stages onto
//! one physical PE at different cycles recovers the idle slots.  We model
//! the recoverable fraction with a per-task *mux efficiency*: the
//! fraction of naive-unroll resources that time-multiplexing eliminates
//! on top of the shared-infrastructure savings from [`super::unroll`].

use crate::abstraction::SliceDemand;

/// Apply time-multiplex optimization to a naively-unrolled demand.
///
/// * `base` — the 1× variant's demand.
/// * `naive` — the k×-unrolled demand (replication).
/// * `mux_efficiency` — fraction of the *added* array slices recovered
///   (0 = no optimization, returns `naive`; 1 = perfect sharing, returns
///   `base`).  GLB slices are never reduced — staging is already shared.
pub fn time_multiplex(base: &SliceDemand, naive: &SliceDemand, mux_efficiency: f64) -> SliceDemand {
    assert!(
        (0.0..=1.0).contains(&mux_efficiency),
        "mux_efficiency must be in [0,1], got {mux_efficiency}"
    );
    debug_assert!(naive.array_slices >= base.array_slices);
    let added = naive.array_slices - base.array_slices;
    let kept = (added as f64 * (1.0 - mux_efficiency)).ceil() as u32;
    SliceDemand::new(naive.glb_slices, base.array_slices + kept)
}

/// Mux efficiency of the paper's camera-pipeline example: 4→16 naive
/// slices optimized to 6, i.e. 10 of the 12 added slices recovered.
pub const CAMERA_MUX_EFFICIENCY: f64 = 10.0 / 12.0;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_camera_16_to_6_example() {
        // base: 4 array slices @ 3 px/cyc; naive ×4: 16 slices.
        let base = SliceDemand::new(4, 4);
        let naive = SliceDemand::new(14, 16); // variant b GLB = 14
        let opt = time_multiplex(&base, &naive, CAMERA_MUX_EFFICIENCY);
        assert_eq!(opt.array_slices, 6); // 4 + ceil(12 * (1 - 10/12)) = 6
        assert_eq!(opt.glb_slices, 14);
    }

    #[test]
    fn zero_efficiency_keeps_naive() {
        let base = SliceDemand::new(4, 2);
        let naive = SliceDemand::new(4, 8);
        assert_eq!(time_multiplex(&base, &naive, 0.0), naive);
    }

    #[test]
    fn full_efficiency_collapses_to_base_array() {
        let base = SliceDemand::new(4, 2);
        let naive = SliceDemand::new(6, 8);
        let opt = time_multiplex(&base, &naive, 1.0);
        assert_eq!(opt.array_slices, 2);
        assert_eq!(opt.glb_slices, 6);
    }

    #[test]
    #[should_panic]
    fn out_of_range_efficiency_panics() {
        time_multiplex(&SliceDemand::new(1, 1), &SliceDemand::new(1, 2), 1.5);
    }
}
