//! Physical placement: slice allocation → tile coordinates.
//!
//! The coarse mapper hands the scheduler slice *counts*; this pass pins a
//! variant's tiles to concrete columns once a region is allocated.  It is
//! also where bitstream relocation becomes concrete (§2.3): the compiler
//! places every task against the **leftmost** region (region-agnostic
//! column ids 0..n), and [`relocate`] shifts the placement to the
//! destination slice — exactly what the destination-region register does
//! in hardware when a GLB bank streams the cached bitstream.

use crate::abstraction::{ArraySliceId, SliceDemand, SliceRange};
use crate::arch::{Geometry, TileCoord, TileKind};
use crate::error::{Error, Result};

/// One placed tile assignment.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PlacedTile {
    /// Physical coordinate.
    pub coord: TileCoord,
    /// Role the mapping assigns (PE compute lane or MEM buffer).
    pub kind: TileKind,
}

/// A variant's physical placement: the tiles it occupies, in the
/// column-major streaming order fast-DPR configures them.
#[derive(Clone, Debug, PartialEq)]
pub struct Placement {
    /// Occupied tiles, column-major.
    pub tiles: Vec<PlacedTile>,
    /// Array-slices covered (contiguous).
    pub slices: SliceRange,
}

impl Placement {
    /// Number of PE tiles placed.
    pub fn pe_count(&self) -> usize {
        self.tiles.iter().filter(|t| t.kind == TileKind::Pe).count()
    }

    /// Number of MEM tiles placed.
    pub fn mem_count(&self) -> usize {
        self.tiles.iter().filter(|t| t.kind == TileKind::Mem).count()
    }

    /// Leftmost column used.
    pub fn min_col(&self) -> u32 {
        self.tiles.iter().map(|t| t.coord.col).min().unwrap_or(0)
    }

    /// Rightmost column used.
    pub fn max_col(&self) -> u32 {
        self.tiles.iter().map(|t| t.coord.col).max().unwrap_or(0)
    }
}

/// Place a variant's demand against the leftmost region (region-agnostic
/// placement, the compiler's output).  Tiles fill column-major across
/// the demanded array-slices — the order the per-slice DPR streams walk.
pub fn place_leftmost(geom: &Geometry, demand: &SliceDemand) -> Result<Placement> {
    let slices = demand.array_slices.max(1);
    if slices > geom.arch().array_slices() {
        return Err(Error::Alloc(format!(
            "demand of {} array slices exceeds the {}-slice array",
            slices,
            geom.arch().array_slices()
        )));
    }
    let mut tiles = Vec::new();
    for s in 0..slices {
        for tile in geom.slice_tiles(ArraySliceId(s)) {
            tiles.push(PlacedTile { coord: tile.coord, kind: tile.kind });
        }
    }
    tiles.sort_by_key(|t| t.coord);
    Ok(Placement { tiles, slices: SliceRange::new(0, slices) })
}

/// Relocate a leftmost placement to `dest` — the software model of the
/// destination-region register.  Requires homogeneous slices (checked at
/// geometry build); the shift is a pure column offset.
pub fn relocate(geom: &Geometry, placement: &Placement, dest: &SliceRange) -> Result<Placement> {
    if placement.slices.start != 0 {
        return Err(Error::Dpr("relocate() expects a leftmost placement".into()));
    }
    if dest.len != placement.slices.len {
        return Err(Error::Dpr(format!(
            "destination {} does not match placement width {}",
            dest, placement.slices.len
        )));
    }
    if dest.end() > geom.arch().array_slices() {
        return Err(Error::Dpr(format!("destination {dest} out of range")));
    }
    let col_shift = dest.start * geom.arch().slice_cols;
    let tiles = placement
        .tiles
        .iter()
        .map(|t| PlacedTile {
            coord: TileCoord { col: t.coord.col + col_shift, row: t.coord.row },
            kind: t.kind,
        })
        .collect();
    Ok(Placement { tiles, slices: *dest })
}

/// Verify a relocated placement is physically valid: every tile lands on
/// a tile of the same kind (this is exactly the homogeneity property
/// that makes region-agnostic bitstreams sound).
pub fn verify_placement(geom: &Geometry, placement: &Placement) -> Result<()> {
    for t in &placement.tiles {
        let phys = geom.tile(t.coord)?;
        if phys.kind != t.kind {
            return Err(Error::Dpr(format!(
                "placement kind mismatch at {}: wants {:?}, tile is {:?}",
                t.coord, t.kind, phys.kind
            )));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ArchConfig;

    fn geom() -> Geometry {
        Geometry::new(&ArchConfig::default()).unwrap()
    }

    #[test]
    fn leftmost_placement_counts_match_slice_geometry() {
        let g = geom();
        let p = place_leftmost(&g, &SliceDemand::new(7, 2)).unwrap();
        assert_eq!(p.pe_count(), 96); // 2 slices × 48
        assert_eq!(p.mem_count(), 32);
        assert_eq!(p.min_col(), 0);
        assert_eq!(p.max_col(), 7); // 2 slices × 4 cols − 1
        verify_placement(&g, &p).unwrap();
    }

    #[test]
    fn relocation_shifts_columns_and_stays_valid() {
        let g = geom();
        let p = place_leftmost(&g, &SliceDemand::new(4, 2)).unwrap();
        for dest_start in 0..=6u32 {
            let dest = SliceRange::new(dest_start, 2);
            let moved = relocate(&g, &p, &dest).unwrap();
            assert_eq!(moved.min_col(), dest_start * 4);
            assert_eq!(moved.pe_count(), p.pe_count());
            // homogeneity ⇒ every destination is physically valid
            verify_placement(&g, &moved).unwrap();
        }
    }

    #[test]
    fn relocation_rejects_bad_destinations() {
        let g = geom();
        let p = place_leftmost(&g, &SliceDemand::new(4, 2)).unwrap();
        assert!(relocate(&g, &p, &SliceRange::new(7, 2)).is_err()); // off the edge
        assert!(relocate(&g, &p, &SliceRange::new(0, 3)).is_err()); // width mismatch
        let moved = relocate(&g, &p, &SliceRange::new(2, 2)).unwrap();
        assert!(relocate(&g, &moved, &SliceRange::new(0, 2)).is_err()); // not leftmost
    }

    #[test]
    fn oversized_demand_rejected() {
        let g = geom();
        assert!(place_leftmost(&g, &SliceDemand::new(4, 9)).is_err());
    }

    #[test]
    fn heterogeneous_shift_would_be_caught() {
        // shift by a non-slice multiple misaligns PE/MEM columns; build
        // such a placement by hand and confirm verify_placement rejects.
        let g = geom();
        let p = place_leftmost(&g, &SliceDemand::new(4, 1)).unwrap();
        let skewed = Placement {
            tiles: p
                .tiles
                .iter()
                .map(|t| PlacedTile {
                    coord: TileCoord { col: t.coord.col + 1, row: t.coord.row },
                    kind: t.kind,
                })
                .collect(),
            slices: SliceRange::new(0, 1),
        };
        assert!(verify_placement(&g, &skewed).is_err());
    }
}
