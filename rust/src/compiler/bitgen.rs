//! Bitstream generation: slice demand → region-agnostic bitstream (§2.3).
//!
//! "Our compiler generates region-agnostic bitstreams by assuming that
//! the task is always mapped to the leftmost region."
//!
//! The word count comes from per-tile configuration-register budgets
//! (PE functional config, MEM controller config, switch/connection-box
//! routing), multiplied out over the slices the variant occupies.  With
//! the default DprConfig this lands at ≈26 KB per array-slice, consistent
//! with Amber's published full-array bitstream scale (~1.4 MB for 32
//! columns with routing).

use crate::abstraction::SliceDemand;
use crate::arch::Interconnect;
use crate::config::{ArchConfig, DprConfig};
use crate::dpr::{Bitstream, BitstreamId};

/// Config words for one array-slice.
pub fn words_per_slice(arch: &ArchConfig, dpr: &DprConfig) -> u64 {
    let ic = Interconnect::new(arch);
    let pe = arch.pe_tiles_per_slice() as u64 * dpr.pe_config_words as u64;
    let mem = arch.mem_tiles_per_slice() as u64 * dpr.mem_config_words as u64;
    let tiles = (arch.pe_tiles_per_slice() + arch.mem_tiles_per_slice()) as u64;
    let route = tiles * ic.route_words_per_tile(dpr.route_config_words) as u64;
    pe + mem + route
}

/// Generate the bitstream for a task variant.
pub fn generate_bitstream(
    task: &str,
    ver: char,
    demand: &SliceDemand,
    arch: &ArchConfig,
    dpr: &DprConfig,
) -> Bitstream {
    let words = words_per_slice(arch, dpr) * demand.array_slices.max(1) as u64;
    Bitstream {
        id: BitstreamId::new(task, ver),
        words,
        array_slices: demand.array_slices.max(1),
        region_agnostic: dpr.relocation,
        home_slice: 0, // leftmost region by construction
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn per_slice_words_calibration() {
        // 48 PE × 64 + 16 MEM × 96 + 64 tiles × 32 route = 6656 words
        let w = words_per_slice(&ArchConfig::default(), &DprConfig::default());
        assert_eq!(w, 6656);
        // ≈26 KB per slice; full 8-slice array ≈208 KB core config —
        // Amber's ~1.4 MB includes GLB/SoC config we don't reconfigure.
        assert_eq!(w * 4, 26_624);
    }

    #[test]
    fn bitstream_scales_with_array_slices() {
        let arch = ArchConfig::default();
        let dpr = DprConfig::default();
        let b2 = generate_bitstream("t", 'a', &SliceDemand::new(7, 2), &arch, &dpr);
        let b6 = generate_bitstream("t", 'b', &SliceDemand::new(7, 6), &arch, &dpr);
        assert_eq!(b2.words * 3, b6.words);
        assert_eq!(b2.words_per_slice(), b6.words_per_slice());
    }

    #[test]
    fn relocation_flag_tracks_config() {
        let arch = ArchConfig::default();
        let mut dpr = DprConfig::default();
        let b = generate_bitstream("t", 'a', &SliceDemand::new(1, 1), &arch, &dpr);
        assert!(b.region_agnostic);
        dpr.relocation = false;
        let b2 = generate_bitstream("t", 'a', &SliceDemand::new(1, 1), &arch, &dpr);
        assert!(!b2.region_agnostic);
    }

    #[test]
    fn zero_array_demand_still_one_slice() {
        let b = generate_bitstream(
            "t",
            'a',
            &SliceDemand::new(1, 0),
            &ArchConfig::default(),
            &DprConfig::default(),
        );
        assert_eq!(b.array_slices, 1);
        assert!(b.words > 0);
    }
}
