//! The coarse-grained "compiler" side of the abstraction (§2.2).
//!
//! The real Amber toolchain compiles a task into a dataflow graph whose
//! nodes are hardware resources; this module reproduces the parts of
//! that flow the paper's mechanisms depend on:
//!
//! 1. [`dfg`] — a dataflow-graph IR whose nodes are GLB banks, PE ops,
//!    and MEM buffers, built from real layer shapes for each Table 1 task.
//! 2. [`mapper`] — derives the raw resource usage (bytes, bandwidth, tile
//!    counts) of a DFG and quantizes it into a
//!    [`crate::abstraction::SliceDemand`] — the §2.2 worked example.
//! 3. [`unroll`] — the variant generator: replicates the compute subgraph
//!    for k× throughput (Fig. 2b's parallel mapping).
//! 4. [`timemux`] — the optimization the variably-sized mechanism
//!    enables: time-multiplexing PE tiles across the merged region so an
//!    unrolled task needs fewer slices than naive replication (the
//!    paper's camera-pipeline 16 → 6 array-slice example).
//! 5. [`bitgen`] — emits region-agnostic [`crate::dpr::Bitstream`]s sized
//!    from per-tile config-register counts.

pub mod bitgen;
pub mod dfg;
pub mod mapper;
pub mod place;
pub mod timemux;
pub mod unroll;

pub use bitgen::generate_bitstream;
pub use dfg::{Dfg, DfgEdge, DfgNode};
pub use mapper::{map_dfg, CompiledVariant};
pub use place::{place_leftmost, relocate, verify_placement, PlacedTile, Placement};
pub use timemux::time_multiplex;
pub use unroll::unroll;
