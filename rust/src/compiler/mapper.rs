//! Coarse resource mapping: DFG → raw usage → slice demand (§2.2).

use crate::abstraction::{RawUsage, SliceDemand};
use crate::config::ArchConfig;
use crate::error::Result;

use super::dfg::{Dfg, DfgNode};

/// A mapped task variant: the compiler's contract with the scheduler.
#[derive(Clone, Debug, PartialEq)]
pub struct CompiledVariant {
    /// Task name the variant was compiled from.
    pub task: String,
    /// Raw, un-quantized usage.
    pub raw: RawUsage,
    /// Quantized slice demand.
    pub demand: SliceDemand,
    /// Achieved throughput in work-units/cycle (MACs or pixels).
    pub throughput: f64,
}

/// Map a DFG onto the architecture, deriving usage and throughput.
///
/// * PE tiles: one per compute lane (a lane sustains 1 MAC/cycle —
///   Amber's PE does one word-level MAC per cycle), plus a 25 % overhead
///   pool for address generators / reduction trees, mirroring how the
///   Amber mapper burns PEs on non-MAC glue.
/// * MEM tiles: one per scratchpad bank, capacity-checked.
/// * GLB: capacity from buffer nodes; bandwidth from GLB-touching edges
///   times the invocation rate.
/// * Throughput: `lanes` MACs/cycle for ML tasks; for pixel tasks the
///   caller should use pixel lanes (`lanes` = pixels/cycle).
pub fn map_dfg(dfg: &Dfg, arch: &ArchConfig) -> Result<CompiledVariant> {
    dfg.validate()?;

    let mut pe_tiles = 0u32;
    let mut mem_tiles = 0u32;
    let mut lanes_total = 0u32;
    for node in &dfg.nodes {
        match node {
            DfgNode::PeCompute { lanes, .. } => {
                // lanes plus 25% glue overhead
                pe_tiles += lanes + lanes.div_ceil(4);
                lanes_total += lanes;
            }
            DfgNode::MemBuffer { bytes, banks } => {
                // each MEM tile holds 4 KB (Amber); a logical bank may
                // need several tiles if deeper than that.
                let per_bank_bytes = (*bytes / (*banks).max(1) as u64).max(1);
                let tiles_per_bank = per_bank_bytes.div_ceil(4096) as u32;
                mem_tiles += banks * tiles_per_bank;
            }
            DfgNode::GlbBuffer { .. } => {}
        }
    }

    let glb_bytes = dfg.glb_bytes();
    let glb_bw = dfg.glb_traffic_bytes() as f64 * dfg.invocations_per_sec;

    let raw = RawUsage {
        glb_bytes,
        glb_bw_bytes_per_sec: glb_bw,
        pe_tiles,
        mem_tiles,
    };
    Ok(CompiledVariant {
        task: dfg.name.clone(),
        raw,
        demand: raw.quantize(arch),
        throughput: lanes_total as f64,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::dfg;

    #[test]
    fn conv2x_maps_to_paper_scale() {
        // §2.2: conv2_x ⇒ 80 PE tiles, 17 MEM tiles, 2 array-slices.
        let arch = ArchConfig::default();
        let v = map_dfg(&dfg::resnet_stage_dfg(2), &arch).unwrap();
        assert_eq!(v.raw.pe_tiles, 80); // 64 lanes + 16 glue
        assert!(v.raw.mem_tiles >= 12 && v.raw.mem_tiles <= 24, "{}", v.raw.mem_tiles);
        assert_eq!(v.demand.array_slices, 2);
        assert_eq!(v.throughput, 64.0);
    }

    #[test]
    fn conv2x_glb_slices_capacity_bound() {
        let arch = ArchConfig::default();
        let v = map_dfg(&dfg::resnet_stage_dfg(2), &arch).unwrap();
        // ~750 KB / 128 KB banks ⇒ 6-8 GLB slices (Table 1 says 7)
        assert!((5..=8).contains(&v.demand.glb_slices), "{}", v.demand.glb_slices);
    }

    #[test]
    fn camera_maps_to_paper_scale() {
        let arch = ArchConfig::default();
        let v = map_dfg(&dfg::camera_dfg(), &arch).unwrap();
        // Table 1: camera a = 4 array slices... mapper yields the raw
        // mapping; pixel tasks burn PEs per stencil tap, so lanes=3
        // pixels/cycle with 12 ops/px ⇒ small PE count; MEM line buffers
        // dominate the slice count.
        assert!(v.demand.array_slices >= 1);
        assert_eq!(v.throughput, 3.0);
    }

    #[test]
    fn mobilenet_groups_fit_two_slices() {
        let arch = ArchConfig::default();
        for g in 2..=4 {
            let v = map_dfg(&dfg::mobilenet_group_dfg(g), &arch).unwrap();
            assert_eq!(v.demand.array_slices, 2, "group {g}");
            // Table 1: 4 GLB slices per group; the first-principles model
            // may land a bank or two off.
            assert!(v.demand.glb_slices <= 6, "group {g}: {}", v.demand.glb_slices);
        }
    }

    #[test]
    fn invalid_dfg_propagates_error() {
        let arch = ArchConfig::default();
        let bad = Dfg {
            name: "bad".into(),
            nodes: vec![],
            edges: vec![super::super::dfg::DfgEdge { from: 0, to: 1, bytes: 1 }],
            invocations_per_sec: 1.0,
        };
        assert!(map_dfg(&bad, &arch).is_err());
    }
}
