//! Dataflow-graph IR.
//!
//! "When a task is compiled in the Amber toolchain, a compiler converts
//! it into a dataflow graph where each node and edge represents a
//! hardware resource and communication, respectively." (§2.2)
//!
//! Nodes model the three resource classes the abstraction cares about;
//! edges carry bytes-per-invocation so GLB bandwidth can be derived.

use crate::error::{Error, Result};
use crate::tasks::workload;

/// One resource node.
#[derive(Clone, Debug, PartialEq)]
pub enum DfgNode {
    /// GLB bank usage: staging buffer of `bytes` capacity.
    GlbBuffer {
        /// Capacity required in bytes.
        bytes: u64,
    },
    /// PE compute: `macs` multiply-accumulates per invocation, to be
    /// spread over `lanes` parallel PE lanes (1 lane ≈ 1 PE tile's MAC).
    PeCompute {
        /// MACs per invocation.
        macs: u64,
        /// Spatial lanes the mapping unrolls across.
        lanes: u32,
    },
    /// MEM-tile scratchpad (line buffers, double buffers).
    MemBuffer {
        /// Capacity in bytes.
        bytes: u64,
        /// Number of independent banks needed (line-buffer rows etc.).
        banks: u32,
    },
}

/// Producer → consumer edge carrying data.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DfgEdge {
    /// Producer node index.
    pub from: usize,
    /// Consumer node index.
    pub to: usize,
    /// Bytes moved per invocation.
    pub bytes: u64,
}

/// A task's dataflow graph.
#[derive(Clone, Debug, PartialEq)]
pub struct Dfg {
    /// Human-readable task name.
    pub name: String,
    /// Resource nodes.
    pub nodes: Vec<DfgNode>,
    /// Communication edges.
    pub edges: Vec<DfgEdge>,
    /// Invocations per second the task must sustain (drives bandwidth).
    pub invocations_per_sec: f64,
}

impl Dfg {
    /// Validate edge indices.
    pub fn validate(&self) -> Result<()> {
        for e in &self.edges {
            if e.from >= self.nodes.len() || e.to >= self.nodes.len() {
                return Err(Error::Config(format!(
                    "DFG '{}' edge {}→{} out of range",
                    self.name, e.from, e.to
                )));
            }
            if e.from == e.to {
                return Err(Error::Config(format!("DFG '{}' self-edge at {}", self.name, e.from)));
            }
        }
        Ok(())
    }

    /// Total MACs per invocation.
    pub fn total_macs(&self) -> u64 {
        self.nodes
            .iter()
            .map(|n| match n {
                DfgNode::PeCompute { macs, .. } => *macs,
                _ => 0,
            })
            .sum()
    }

    /// Total GLB bytes.
    pub fn glb_bytes(&self) -> u64 {
        self.nodes
            .iter()
            .map(|n| match n {
                DfgNode::GlbBuffer { bytes } => *bytes,
                _ => 0,
            })
            .sum()
    }

    /// Bytes crossing GLB↔array per invocation (edges touching GLB nodes).
    pub fn glb_traffic_bytes(&self) -> u64 {
        self.edges
            .iter()
            .filter(|e| {
                matches!(self.nodes[e.from], DfgNode::GlbBuffer { .. })
                    || matches!(self.nodes[e.to], DfgNode::GlbBuffer { .. })
            })
            .map(|e| e.bytes)
            .sum()
    }
}

/// Build the canonical DFG of a ResNet-18 stage (`conv{n}_x`).
///
/// Weights + activations stage through the GLB; the four (five with
/// projection) convs run as PE compute fed by MEM line buffers.
pub fn resnet_stage_dfg(stage: u32) -> Dfg {
    let macs = workload::resnet18_stage_macs(stage);
    // activation sizes at the stage's working resolution (f32)
    let (hw, ch): (u64, u64) = match stage {
        2 => (56, 64),
        3 => (28, 128),
        4 => (14, 256),
        5 => (7, 512),
        _ => panic!("stage 2..=5"),
    };
    // Amber is a 16-bit-word CGRA: 2 bytes per activation/weight.
    let act_bytes = hw * hw * ch * 2;
    // weights: 4 convs of 3x3xCxC (+ a 1x1 projection for stages 3-5).
    // Deep-stage weights exceed the GLB, so the mapping streams them as
    // double-buffered panels (≤256 KB resident) — this is why Table 1's
    // GLB columns do not grow with layer depth.
    let w_in = if stage == 2 { ch } else { ch / 2 };
    let weight_bytes = (3 * 3 * w_in * ch + 3 * 3 * ch * ch * 3
        + if stage == 2 { 0 } else { w_in * ch }) * 2;
    let weight_panel = weight_bytes.min(256 * 1024) * 2; // double-buffered
    let nodes = vec![
        DfgNode::GlbBuffer { bytes: weight_panel },           // 0: weight panels
        DfgNode::GlbBuffer { bytes: act_bytes },              // 1: act ping-pong
        // 16 line/weight-panel buffers per stage (paper's worked example
        // counts 17 MEM tiles for conv2_x).
        DfgNode::MemBuffer { bytes: hw * ch * 2 * 6, banks: 16 }, // 2: line buffers
        DfgNode::PeCompute { macs, lanes: 64 },               // 3: MAC network
    ];
    let edges = vec![
        DfgEdge { from: 0, to: 3, bytes: weight_bytes },
        DfgEdge { from: 1, to: 2, bytes: act_bytes },
        DfgEdge { from: 2, to: 3, bytes: act_bytes },
        DfgEdge { from: 3, to: 1, bytes: act_bytes },
    ];
    Dfg {
        name: format!("resnet18.conv{stage}_x"),
        nodes,
        edges,
        // one inference stream at 30 inf/s is the sizing point
        invocations_per_sec: 30.0,
    }
}

/// Build the canonical DFG of a MobileNet merged dw+pw group.
pub fn mobilenet_group_dfg(group: u32) -> Dfg {
    let macs = workload::mobilenet_group_macs(group);
    let (hw, ch): (u64, u64) = match group {
        2 => (56, 128),
        3 => (28, 256),
        4 => (14, 512),
        _ => panic!("group 2..=4"),
    };
    let act_bytes = hw * hw * ch * 2;
    let weight_bytes = (9 * ch / 2 + (ch / 2) * ch + 9 * ch + ch * ch) * 2;
    let weight_panel = weight_bytes.min(128 * 1024) * 2;
    let nodes = vec![
        DfgNode::GlbBuffer { bytes: weight_panel },
        // depthwise stages stream activations band-wise: half-tensor
        // staging is enough (the dw stencil is row-local).
        DfgNode::GlbBuffer { bytes: act_bytes / 2 },
        DfgNode::MemBuffer { bytes: hw * ch * 2 * 3, banks: 4 },
        DfgNode::PeCompute { macs, lanes: 52 },
    ];
    let edges = vec![
        DfgEdge { from: 0, to: 3, bytes: weight_bytes },
        DfgEdge { from: 1, to: 2, bytes: act_bytes },
        DfgEdge { from: 2, to: 3, bytes: act_bytes },
        DfgEdge { from: 3, to: 1, bytes: act_bytes },
    ];
    Dfg {
        name: format!("mobilenet.conv_dw_pw_{group}_x"),
        nodes,
        edges,
        invocations_per_sec: 30.0,
    }
}

/// Build the camera-pipeline DFG (RAW in, RGB out, stencil stages).
pub fn camera_dfg() -> Dfg {
    let px = workload::frame_pixels();
    let raw_bytes = px; // 8-bit RAW
    let rgb_bytes = px * 3;
    let nodes = vec![
        DfgNode::GlbBuffer { bytes: 256 * 1024 },             // 0: tile staging
        DfgNode::MemBuffer { bytes: 1920 * 2 * 4, banks: 8 }, // 1: line buffers
        DfgNode::PeCompute { macs: px * 12, lanes: 3 },       // 2: demosaic+wb+ccm+gamma
    ];
    let edges = vec![
        DfgEdge { from: 0, to: 1, bytes: raw_bytes },
        DfgEdge { from: 1, to: 2, bytes: raw_bytes },
        DfgEdge { from: 2, to: 0, bytes: rgb_bytes },
    ];
    Dfg { name: "camera.pipeline".into(), nodes, edges, invocations_per_sec: 30.0 }
}

/// Build the Harris corner-detector DFG.
pub fn harris_dfg() -> Dfg {
    let px = workload::frame_pixels();
    let nodes = vec![
        DfgNode::GlbBuffer { bytes: 256 * 1024 },
        DfgNode::MemBuffer { bytes: 1920 * 4 * 4, banks: 10 }, // deeper stencil
        DfgNode::PeCompute { macs: px * 18, lanes: 1 },        // grads+tensor+window+R
    ];
    let edges = vec![
        DfgEdge { from: 0, to: 1, bytes: px },
        DfgEdge { from: 1, to: 2, bytes: px },
        DfgEdge { from: 2, to: 0, bytes: px * 4 },
    ];
    Dfg { name: "harris.corner".into(), nodes, edges, invocations_per_sec: 30.0 }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_canonical_dfgs_validate() {
        for stage in 2..=5 {
            resnet_stage_dfg(stage).validate().unwrap();
        }
        for group in 2..=4 {
            mobilenet_group_dfg(group).validate().unwrap();
        }
        camera_dfg().validate().unwrap();
        harris_dfg().validate().unwrap();
    }

    #[test]
    fn resnet_macs_match_workload() {
        assert_eq!(resnet_stage_dfg(2).total_macs(), workload::resnet18_stage_macs(2));
    }

    #[test]
    fn conv2x_glb_footprint_near_paper_750kb() {
        // §2.2: "a conv2_x layer utilizes 750KB of GLB memory capacity".
        // Our stage-level model (weight panels + act ping-pong) lands in
        // the same regime; Table 1 remains the authoritative slice count.
        let kb = resnet_stage_dfg(2).glb_bytes() / 1024;
        assert!((600..=1100).contains(&kb), "{kb} KB");
    }

    #[test]
    fn glb_traffic_counts_only_glb_edges() {
        let d = camera_dfg();
        // raw in (via edge 0→1) + rgb out (2→0)
        assert_eq!(d.glb_traffic_bytes(), workload::frame_pixels() * 4);
    }

    #[test]
    fn invalid_edges_rejected() {
        let bad = Dfg {
            name: "bad".into(),
            nodes: vec![DfgNode::GlbBuffer { bytes: 1 }],
            edges: vec![DfgEdge { from: 0, to: 1, bytes: 1 }],
            invocations_per_sec: 1.0,
        };
        assert!(bad.validate().is_err());
        let selfloop = Dfg {
            name: "self".into(),
            nodes: vec![DfgNode::GlbBuffer { bytes: 1 }, DfgNode::GlbBuffer { bytes: 1 }],
            edges: vec![DfgEdge { from: 1, to: 1, bytes: 1 }],
            invocations_per_sec: 1.0,
        };
        assert!(selfloop.validate().is_err());
    }
}
