"""Tests for the L2 HLO audit (compile.audit)."""

import os

import pytest

from compile import audit


def test_audit_text_counts_ops():
    text = (
        "HloModule m\n"
        "  while.1 = while(x), body=b\n"
        "  d = f32[2] dynamic-slice(a, c)\n"
        "  e = f32[2] dynamic-update-slice(a, b, c)\n"
        "  f = f32[2,2] dot(g, h)\n"
        "  p = f32[2] power(a, b)\n"
    )
    c = audit.audit_text("demo", text)
    assert c["while"] == 1
    assert c["dynamic-slice"] == 1
    assert c["dynamic-update-slice"] == 1
    assert c["dot"] == 1
    assert c["power"] == 1
    assert c["convolution"] == 0
    assert c["elided_constants"] == 0


def test_check_flags_elided_constants():
    c = audit.audit_text("bad", "x = f32[128,128] constant({...})\n")
    problems = audit.check(c)
    assert len(problems) == 1
    assert "elided" in problems[0]


def test_check_flags_convolutions():
    c = audit.audit_text("conv", "y = f32[1,2,2,3] convolution(a, b)\n")
    problems = audit.check(c)
    assert len(problems) == 1
    assert "convolution" in problems[0]


def test_clean_module_passes():
    c = audit.audit_text("ok", "HloModule m\n  f = f32[2,2] dot(g, h)\n")
    assert audit.check(c) == []


@pytest.mark.skipif(
    not os.path.exists(os.path.join(os.path.dirname(__file__), "../../artifacts/manifest.json")),
    reason="artifacts not built",
)
def test_built_artifacts_pass_audit():
    root = os.path.join(os.path.dirname(__file__), "../../artifacts")
    for fname in sorted(os.listdir(root)):
        if not fname.endswith(".hlo.txt"):
            continue
        with open(os.path.join(root, fname)) as f:
            counts = audit.audit_text(fname, f.read())
        assert audit.check(counts) == [], fname
        # every artifact's compute is dot/stencil structured: bounded loops
        assert counts["while"] <= 4, f"{fname}: {counts['while']} loops"
