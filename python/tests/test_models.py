"""Layer-2 model-graph checks: shapes, ranges, determinism, composition."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile import model


KEY = jax.random.PRNGKey(42)


# ---------------------------------------------------------------------------
# ResNet stages
# ---------------------------------------------------------------------------


def test_resnet_stage_shapes_no_downsample():
    p = model.resnet_stage_params(KEY, 8, 8, downsample=False)
    x = jax.random.normal(KEY, (2, 16, 16, 8), jnp.float32)
    y = model.resnet_stage(x, p, downsample=False)
    assert y.shape == (2, 16, 16, 8)


def test_resnet_stage_shapes_downsample():
    p = model.resnet_stage_params(KEY, 8, 16, downsample=True)
    x = jax.random.normal(KEY, (1, 16, 16, 8), jnp.float32)
    y = model.resnet_stage(x, p, downsample=True)
    assert y.shape == (1, 8, 8, 16)


def test_resnet_stage_chain_composes():
    """conv2_x → conv3_x → conv4_x → conv5_x like the app DAG in rust."""
    x = jax.random.normal(KEY, (1, 16, 16, 8), jnp.float32)
    chans = [(8, 8, False), (8, 16, True), (16, 32, True), (32, 64, True)]
    for i, (cin, cout, down) in enumerate(chans):
        p = model.resnet_stage_params(jax.random.PRNGKey(i), cin, cout, downsample=down)
        x = model.resnet_stage(x, p, downsample=down)
    assert x.shape == (1, 2, 2, 64)


def test_resnet_stage_relu_output_nonnegative():
    p = model.resnet_stage_params(KEY, 4, 4, downsample=False)
    x = jax.random.normal(KEY, (1, 8, 8, 4), jnp.float32)
    y = model.resnet_stage(x, p, downsample=False)
    assert float(jnp.min(y)) >= 0.0


def test_resnet_params_deterministic():
    p1 = model.resnet_stage_params(KEY, 4, 8)
    p2 = model.resnet_stage_params(KEY, 4, 8)
    for k in p1:
        assert_allclose(p1[k], p2[k])


# ---------------------------------------------------------------------------
# MobileNet stages
# ---------------------------------------------------------------------------


def test_mobilenet_stage_shape_and_range():
    p = model.mobilenet_stage_params(KEY, 8, 16)
    x = jax.random.normal(KEY, (12, 10, 8), jnp.float32)
    y = model.mobilenet_dw_pw(x, p["wdw"], p["wpw"])
    assert y.shape == (12, 10, 16)
    assert float(jnp.min(y)) >= 0.0  # relu


def test_mobilenet_batched_matches_loop():
    p = model.mobilenet_stage_params(KEY, 4, 8)
    xb = jax.random.normal(KEY, (3, 8, 8, 4), jnp.float32)
    fn = model.batched(lambda xi: model.mobilenet_dw_pw(xi, p["wdw"], p["wpw"]))
    yb = fn(xb)
    for i in range(3):
        yi = model.mobilenet_dw_pw(xb[i], p["wdw"], p["wpw"])
        assert_allclose(yb[i], yi, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Camera pipeline
# ---------------------------------------------------------------------------


def test_camera_pipeline_output_in_unit_range():
    raw = jax.random.uniform(KEY, (32, 32), jnp.float32)
    rgb = model.camera_pipeline(raw)
    assert rgb.shape == (32, 32, 3)
    assert float(jnp.min(rgb)) >= 0.0
    assert float(jnp.max(rgb)) <= 1.0


def test_camera_pipeline_grey_world_stays_grey():
    """CCM rows sum to 1, so a WB-corrected grey field stays grey."""
    # Construct RAW whose demosaic+WB gives equal R=G=B everywhere:
    # set R sites to g/2.0, B sites to g/1.6, G sites to g (inverse gains).
    g = 0.4
    rows = jnp.arange(16)[:, None]
    cols = jnp.arange(16)[None, :]
    even_r, even_c = (rows % 2) == 0, (cols % 2) == 0
    raw = jnp.where(
        even_r & even_c, g / 2.0, jnp.where(~even_r & ~even_c, g / 1.6, g)
    ).astype(jnp.float32)
    rgb = np.asarray(model.camera_pipeline(raw))
    spread = rgb.max(axis=-1) - rgb.min(axis=-1)
    assert spread.max() < 1e-3


def test_camera_pipeline_monotone_in_exposure():
    raw_lo = jnp.full((16, 16), 0.2, jnp.float32)
    raw_hi = jnp.full((16, 16), 0.4, jnp.float32)
    lo = np.asarray(model.camera_pipeline(raw_lo))
    hi = np.asarray(model.camera_pipeline(raw_hi))
    assert (hi >= lo - 1e-6).all()


# ---------------------------------------------------------------------------
# Harris detector
# ---------------------------------------------------------------------------


def test_harris_detect_normalized():
    img = jax.random.uniform(KEY, (40, 40), jnp.float32)
    resp = model.harris_detect(img)
    assert resp.shape == (40, 40)
    assert float(jnp.max(jnp.abs(resp))) <= 1.0 + 1e-6


def test_harris_detect_scale_invariant():
    """Normalization makes the response contrast-invariant."""
    img = jax.random.uniform(KEY, (24, 24), jnp.float32)
    r1 = model.harris_detect(img)
    r2 = model.harris_detect(img * 3.0)
    assert_allclose(r1, r2, rtol=1e-4, atol=1e-5)
