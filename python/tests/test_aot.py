"""AOT pipeline checks: registry coverage, golden inputs, HLO emission."""

import json
import math
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model


def test_golden_input_deterministic_and_in_range():
    a = aot.golden_input((3, 5, 7), lo=0.0, hi=1.0)
    b = aot.golden_input((3, 5, 7), lo=0.0, hi=1.0)
    assert a.dtype == np.float32
    assert (a == b).all()
    assert a.min() >= 0.0 and a.max() <= 1.0


def test_golden_input_matches_reference_expression():
    """Pin the exact fill expression — rust/src/runtime/inputs.rs mirrors it."""
    a = aot.golden_input((4,), lo=-1.0, hi=1.0)
    phi = 0.6180339887498949
    for i in range(4):
        frac = math.modf((i + 1) * phi)[0]
        assert a[i] == np.float32(-1.0 + 2.0 * frac)


def test_golden_input_salt_streams():
    """Salted streams are distinct, reproducible, and offset-based."""
    a = aot.golden_input((8,), salt=0)
    b = aot.golden_input((8,), salt=1)
    assert (a != b).any()
    assert (b == aot.golden_input((8,), salt=1)).all()
    phi = 0.6180339887498949
    x = (1_000_003 + 1) * phi
    assert b[0] == np.float32(-1.0 + 2.0 * math.modf(x)[0])


def test_checksum_fields():
    cs = aot.checksum(np.asarray([[1.0, -2.0], [3.0, -4.0]]))
    assert cs["sum"] == -2.0
    assert cs["abs_sum"] == 10.0
    assert cs["head"] == [1.0, -2.0, 3.0, -4.0]


def test_registry_covers_table1_tasks():
    """Every Table-1 task family must have artifacts; variant counts match."""
    reg = aot.build_registry("tiny")
    by_task = {}
    for art in reg:
        by_task.setdefault(art.task, []).append(art.variant)
    # ResNet-18: 4 stages x {a,b}
    for s in ("conv2", "conv3", "conv4", "conv5"):
        assert sorted(by_task[f"resnet18.{s}_x"]) == ["a", "b"]
    # MobileNet: 3 stages x {a,b}
    for s in ("dw_pw_2", "dw_pw_3", "dw_pw_4"):
        assert sorted(by_task[f"mobilenet.conv_{s}_x"]) == ["a", "b"]
    assert sorted(by_task["camera.pipeline"]) == ["a", "b"]
    assert sorted(by_task["harris.corner"]) == ["a", "b", "c"]


def test_artifact_names_unique():
    reg = aot.build_registry("tiny")
    names = [a.name for a in reg]
    assert len(names) == len(set(names))


def test_lower_artifact_emits_parseable_hlo(tmp_path):
    reg = [a for a in aot.build_registry("tiny") if a.name == "harris_a"]
    assert len(reg) == 1
    entry = aot.lower_artifact(reg[0], str(tmp_path))
    text = (tmp_path / entry["file"]).read_text()
    # HLO text module header + an entry computation
    assert text.startswith("HloModule")
    assert "ENTRY" in text
    assert entry["golden"]["abs_sum"] > 0.0
    assert all(i["dtype"] == "f32" for i in entry["inputs"])


def test_lowered_variant_b_scales_batch(tmp_path):
    arts = {a.name: a for a in aot.build_registry("tiny")}
    a, b = arts["resnet_conv2_a"], arts["resnet_conv2_b"]
    assert b.inputs[0].shape[0] == 4 * a.inputs[0].shape[0]
    assert a.inputs[0].shape[1:] == b.inputs[0].shape[1:]
    # weight arguments identical across variants of a task
    assert [t.shape for t in a.inputs[1:]] == [t.shape for t in b.inputs[1:]]


def test_weights_are_arguments_not_constants():
    """Guard the constant-elision failure mode: every artifact's weights
    must be runtime arguments."""
    for art in aot.build_registry("tiny"):
        if art.task.startswith(("resnet18", "mobilenet", "micro")):
            assert len(art.inputs) >= 2, art.name
            assert any(t.role == "weight" for t in art.inputs[1:]), art.name


def test_golden_checksum_reproducible():
    """Lowered fn on golden input must give identical checksum across runs."""
    art = [a for a in aot.build_registry("tiny") if a.name == "camera_pipeline_a"][0]
    args = aot.golden_args(art)
    y1 = np.asarray(jax.jit(art.fn)(*args))
    y2 = np.asarray(jax.jit(art.fn)(*args))
    assert aot.checksum(y1) == aot.checksum(y2)


@pytest.mark.skipif(
    not os.path.exists(os.path.join(os.path.dirname(__file__), "../../artifacts/manifest.json")),
    reason="artifacts not built",
)
def test_built_manifest_consistent():
    """If `make artifacts` has run, the manifest must match files on disk."""
    root = os.path.join(os.path.dirname(__file__), "../../artifacts")
    with open(os.path.join(root, "manifest.json")) as f:
        man = json.load(f)
    assert man["version"] == aot.MANIFEST_VERSION
    for entry in man["artifacts"]:
        path = os.path.join(root, entry["file"])
        assert os.path.exists(path), entry["file"]
        assert entry["hlo_bytes"] == os.path.getsize(path)
        assert all(i["dtype"] == "f32" for i in entry["inputs"])
        assert len(entry["golden"]["head"]) <= 8
        text = open(path).read()
        assert "constant({...})" not in text, entry["name"]
