"""Kernel-vs-reference correctness: the core L1 signal.

Hypothesis sweeps shapes (and block sizes, so padding/ragged-edge paths are
exercised) and asserts allclose against the pure-jnp oracles in ref.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import (
    conv2d_im2col,
    demosaic_rggb,
    depthwise_conv,
    harris_response,
    matmul_mac,
)
from compile.kernels import ref

SETTINGS = dict(max_examples=12, deadline=None)


def _rand(key, shape, lo=-1.0, hi=1.0):
    return jax.random.uniform(jax.random.PRNGKey(key), shape, jnp.float32, lo, hi)


# ---------------------------------------------------------------------------
# matmul
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    m=st.integers(1, 70),
    k=st.integers(1, 70),
    n=st.integers(1, 70),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_matches_ref(m, k, n, seed):
    x = _rand(seed, (m, k))
    w = _rand(seed + 1, (k, n))
    got = matmul_mac(x, w)
    want = ref.matmul_ref(x, w)
    assert got.shape == (m, n)
    assert got.dtype == jnp.float32
    assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(**SETTINGS)
@given(
    bm=st.sampled_from([8, 16, 32, 64]),
    bn=st.sampled_from([8, 16, 32, 64]),
    bk=st.sampled_from([8, 16, 32, 64]),
)
def test_matmul_block_shape_invariance(bm, bn, bk):
    """Result must not depend on the tiling (the scheduler's unroll knob)."""
    x = _rand(7, (45, 37))
    w = _rand(8, (37, 51))
    got = matmul_mac(x, w, block_m=bm, block_n=bn, block_k=bk)
    assert_allclose(got, ref.matmul_ref(x, w), rtol=1e-5, atol=1e-5)


def test_matmul_rejects_bad_shapes():
    with pytest.raises(ValueError):
        matmul_mac(jnp.zeros((2, 3)), jnp.zeros((4, 5)))
    with pytest.raises(ValueError):
        matmul_mac(jnp.zeros((2, 3, 4)), jnp.zeros((4, 5)))


def test_matmul_bf16_inputs_accumulate_f32():
    x = _rand(3, (33, 17)).astype(jnp.bfloat16)
    w = _rand(4, (17, 9)).astype(jnp.bfloat16)
    got = matmul_mac(x, w)
    assert got.dtype == jnp.float32
    assert_allclose(got, ref.matmul_ref(x, w), rtol=2e-2, atol=2e-2)


# ---------------------------------------------------------------------------
# conv2d
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    n=st.integers(1, 3),
    hw=st.integers(4, 20),
    cin=st.integers(1, 12),
    cout=st.integers(1, 12),
    stride=st.sampled_from([1, 2]),
    seed=st.integers(0, 2**31 - 1),
)
def test_conv2d_matches_ref(n, hw, cin, cout, stride, seed):
    x = _rand(seed, (n, hw, hw, cin))
    w = _rand(seed + 1, (3, 3, cin, cout))
    got = conv2d_im2col(x, w, stride=stride)
    want = ref.conv2d_ref(x, w, stride=stride)
    assert got.shape == want.shape
    assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_conv2d_1x1_projection():
    """The ResNet skip-path projection: 1x1, stride 2, no padding."""
    x = _rand(11, (2, 8, 8, 6))
    w = _rand(12, (1, 1, 6, 10))
    got = conv2d_im2col(x, w, stride=2, padding=0)
    want = ref.conv2d_ref(x, w, stride=2, padding=0)
    assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_conv2d_rejects_channel_mismatch():
    with pytest.raises(ValueError):
        conv2d_im2col(jnp.zeros((1, 4, 4, 3)), jnp.zeros((3, 3, 5, 2)))


# ---------------------------------------------------------------------------
# depthwise
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    h=st.integers(3, 24),
    w=st.integers(3, 24),
    c=st.integers(1, 40),
    bc=st.sampled_from([4, 8, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_depthwise_matches_ref(h, w, c, bc, seed):
    x = _rand(seed, (h, w, c))
    wts = _rand(seed + 1, (3, 3, c))
    got = depthwise_conv(x, wts, block_c=bc)
    assert got.shape == (h, w, c)
    assert_allclose(got, ref.depthwise_ref(x, wts), rtol=1e-5, atol=1e-5)


def test_depthwise_5x5_taps():
    x = _rand(21, (10, 11, 6))
    wts = _rand(22, (5, 5, 6))
    got = depthwise_conv(x, wts)
    assert_allclose(got, ref.depthwise_ref(x, wts), rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# demosaic
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    h=st.integers(2, 32).map(lambda v: v * 2),
    w=st.integers(2, 32).map(lambda v: v * 2),
    bh=st.sampled_from([2, 8, 16, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_demosaic_matches_ref(h, w, bh, seed):
    raw = _rand(seed, (h, w), 0.0, 1.0)
    got = demosaic_rggb(raw, block_h=bh)
    assert got.shape == (h, w, 3)
    assert_allclose(got, ref.demosaic_ref(raw), rtol=1e-5, atol=1e-6)


def test_demosaic_constant_raw_is_constant_rgb():
    """A flat RAW field must demosaic to a flat image in every channel."""
    raw = jnp.full((16, 16), 0.25, jnp.float32)
    rgb = demosaic_rggb(raw, block_h=8)
    assert_allclose(rgb, jnp.full((16, 16, 3), 0.25), atol=1e-6)


def test_demosaic_rejects_odd_dims():
    with pytest.raises(ValueError):
        demosaic_rggb(jnp.zeros((15, 16)))
    with pytest.raises(ValueError):
        demosaic_rggb(jnp.zeros((16, 16)), block_h=7)


# ---------------------------------------------------------------------------
# harris
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    h=st.integers(5, 48),
    w=st.integers(5, 48),
    bh=st.sampled_from([4, 16, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_harris_matches_ref(h, w, bh, seed):
    img = _rand(seed, (h, w), 0.0, 1.0)
    got = harris_response(img, block_h=bh)
    assert got.shape == (h, w)
    assert_allclose(got, ref.harris_ref(img), rtol=1e-3, atol=1e-4)


def test_harris_flat_image_zero_response():
    img = jnp.full((24, 24), 0.5, jnp.float32)
    resp = harris_response(img)
    assert_allclose(resp, jnp.zeros((24, 24)), atol=1e-5)


def test_harris_corner_peaks_at_corner():
    """A bright quadrant's corner should out-score its edges."""
    img = jnp.zeros((32, 32), jnp.float32).at[16:, 16:].set(1.0)
    resp = np.asarray(harris_response(img))
    corner = resp[14:19, 14:19].max()
    edge = resp[14:19, 24:29].max()  # pure edge region
    assert corner > edge
    assert corner > 0.0
