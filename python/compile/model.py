"""Layer-2 JAX compute graphs for the four benchmark applications.

These are the *functional* bodies of the tasks in Table 1 of the paper:

* ResNet-18 conv stages (``conv2_x`` … ``conv5_x``) — residual blocks of
  3x3 convs, built on the Pallas im2col MAC kernel.
* MobileNet ``conv_dw_pw`` stages — depthwise 3x3 (Pallas stencil) +
  pointwise 1x1 (Pallas matmul).
* Camera pipeline — Bayer demosaic (Pallas stencil) → white balance →
  3x3 colour-correction matrix → gamma.
* Harris corner detector — Pallas Harris-response stencil + threshold.

Everything here is traced once by ``aot.py`` and lowered to HLO text; the
Rust coordinator executes the artifacts through PJRT.  The *timing* of the
simulated CGRA comes from Table 1 throughputs (rust/src/tasks); these
graphs provide the *numerics* at a configurable, reduced resolution (the
substitution table in DESIGN.md explains why that preserves the paper's
evaluation).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import (
    conv2d_im2col,
    demosaic_rggb,
    depthwise_conv,
    harris_response,
    matmul_mac,
)

# ---------------------------------------------------------------------------
# ResNet-18 conv stages
# ---------------------------------------------------------------------------


def residual_block(
    x: jax.Array,
    w1: jax.Array,
    w2: jax.Array,
    wproj: jax.Array | None = None,
    *,
    stride: int = 1,
    interpret: bool = True,
) -> jax.Array:
    """One ResNet basic block: conv3x3 → relu → conv3x3 (+skip) → relu."""
    y = conv2d_im2col(x, w1, stride=stride, padding=1, interpret=interpret)
    y = jax.nn.relu(y)
    y = conv2d_im2col(y, w2, stride=1, padding=1, interpret=interpret)
    if wproj is not None:
        # 1x1 strided projection on the skip path (stage entry).
        skip = conv2d_im2col(x, wproj, stride=stride, padding=0, interpret=interpret)
    else:
        skip = x
    return jax.nn.relu(y + skip.astype(y.dtype))


def resnet_stage(
    x: jax.Array,
    params: dict[str, jax.Array],
    *,
    downsample: bool = True,
    interpret: bool = True,
) -> jax.Array:
    """A ResNet-18 conv stage = two basic blocks (conv{2..5}_x in Table 1).

    ``params`` keys: b1w1, b1w2, b1proj (absent if not downsampling),
    b2w1, b2w2.
    """
    stride = 2 if downsample else 1
    proj = params.get("b1proj")
    x = residual_block(
        x, params["b1w1"], params["b1w2"], proj, stride=stride, interpret=interpret
    )
    x = residual_block(x, params["b2w1"], params["b2w2"], None, stride=1, interpret=interpret)
    return x


def resnet_stage_params(
    key: jax.Array, cin: int, cout: int, *, downsample: bool = True
) -> dict[str, jax.Array]:
    """He-init weights for one stage (deterministic given ``key``)."""
    k = jax.random.split(key, 5)

    def he(kk, shape):
        fan_in = shape[0] * shape[1] * shape[2]
        return jax.random.normal(kk, shape, jnp.float32) * jnp.sqrt(2.0 / fan_in)

    params = {
        "b1w1": he(k[0], (3, 3, cin, cout)),
        "b1w2": he(k[1], (3, 3, cout, cout)),
        "b2w1": he(k[2], (3, 3, cout, cout)),
        "b2w2": he(k[3], (3, 3, cout, cout)),
    }
    if downsample:
        params["b1proj"] = he(k[4], (1, 1, cin, cout))
    return params


# ---------------------------------------------------------------------------
# MobileNet conv_dw_pw stages
# ---------------------------------------------------------------------------


def mobilenet_dw_pw(
    x: jax.Array,
    wdw: jax.Array,
    wpw: jax.Array,
    *,
    interpret: bool = True,
) -> jax.Array:
    """Merged depthwise-3x3 + pointwise-1x1 stage (``conv_dw_pw`` in Table 1).

    ``x``: (H, W, C_in); ``wdw``: (3, 3, C_in); ``wpw``: (C_in, C_out).
    """
    y = depthwise_conv(x, wdw, interpret=interpret)
    y = jax.nn.relu(y)
    h, w, c = y.shape
    y = matmul_mac(y.reshape(h * w, c), wpw, interpret=interpret)
    y = y.reshape(h, w, wpw.shape[1])
    return jax.nn.relu(y)


def mobilenet_stage_params(key: jax.Array, cin: int, cout: int) -> dict[str, jax.Array]:
    k1, k2 = jax.random.split(key)
    wdw = jax.random.normal(k1, (3, 3, cin), jnp.float32) * jnp.sqrt(2.0 / 9.0)
    wpw = jax.random.normal(k2, (cin, cout), jnp.float32) * jnp.sqrt(2.0 / cin)
    return {"wdw": wdw, "wpw": wpw}


# ---------------------------------------------------------------------------
# Camera pipeline
# ---------------------------------------------------------------------------

#: Default white-balance gains (R, G, B) and colour-correction matrix —
#: plausible daylight values; the CCM rows sum to 1 so grey stays grey.
WB_GAINS = (2.0, 1.0, 1.6)
CCM = (
    (1.7, -0.5, -0.2),
    (-0.3, 1.6, -0.3),
    (-0.1, -0.6, 1.7),
)
GAMMA = 1.0 / 2.2


def camera_pipeline(raw: jax.Array, *, interpret: bool = True) -> jax.Array:
    """RAW RGGB (H, W) in [0,1] → display RGB (H, W, 3) in [0,1].

    Stages: Pallas bilinear demosaic → white balance → CCM → gamma.
    """
    rgb = demosaic_rggb(raw, interpret=interpret)
    gains = jnp.asarray(WB_GAINS, jnp.float32)
    rgb = rgb * gains
    ccm = jnp.asarray(CCM, jnp.float32)
    rgb = rgb @ ccm.T
    rgb = jnp.clip(rgb, 0.0, 1.0)
    return jnp.power(rgb, GAMMA)


# ---------------------------------------------------------------------------
# Harris corner detector
# ---------------------------------------------------------------------------


def harris_detect(img: jax.Array, *, interpret: bool = True) -> jax.Array:
    """Grayscale (H, W) → Harris response map, normalized to [-1, 1].

    The normalization keeps the artifact's output scale independent of
    image contrast so the Rust integration tests can use fixed tolerances.
    """
    resp = harris_response(img, interpret=interpret)
    scale = jnp.maximum(jnp.max(jnp.abs(resp)), 1e-12)
    return resp / scale


# ---------------------------------------------------------------------------
# Whole-app wrappers used by aot.py (one artifact per task variant)
# ---------------------------------------------------------------------------


def batched(fn):
    """vmap a single-sample graph over a leading batch axis.

    Variant ``b`` of an ML task in Table 1 is the same graph unrolled; at
    the functional level unrolling is a batch axis (the simulated timing
    difference lives in rust/src/tasks).
    """

    def wrapper(x, *args, **kwargs):
        return jax.vmap(lambda xi: fn(xi, *args, **kwargs))(x)

    return wrapper
