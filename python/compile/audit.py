"""L2 HLO audit: op-level cost profile of the lowered artifacts.

Part of the §Perf method (EXPERIMENTS.md): after lowering, inspect each
artifact's HLO for the structures that dominate execution under the
pinned XLA 0.5.1 CPU backend — while-loops (interpret-mode Pallas grids),
dynamic-update-slices (grid output writes), convolutions (accidental —
e.g. `conv_general_dilated_patches` lowers to a real convolution), and
transcendentals.  Run:

    python -m compile.audit [--out-dir ../artifacts]

The audit enforces two invariants the perf pass established:
  * no artifact contains an elided large constant, and
  * no artifact lowers to a `convolution` op (conv must go through the
    Pallas matmul path, not a library conv).
"""

from __future__ import annotations

import argparse
import os
import re
import sys


INTERESTING = (
    "while(",
    "dynamic-update-slice",
    "dynamic-slice",
    "convolution(",
    "dot(",
    "power(",
    "concatenate(",
    "fusion(",
)


def audit_text(name: str, text: str) -> dict:
    """Count interesting ops in one HLO text module."""
    counts = {op.strip("("): text.count(op) for op in INTERESTING}
    counts["lines"] = text.count("\n")
    counts["bytes"] = len(text)
    counts["elided_constants"] = len(re.findall(r"constant\(\{\s*\.\.\.\s*\}\)", text))
    counts["name"] = name
    return counts


def check(counts: dict) -> list[str]:
    """Invariant violations for one artifact."""
    problems = []
    if counts["elided_constants"]:
        problems.append(f"{counts['name']}: {counts['elided_constants']} elided constants")
    if counts["convolution"]:
        problems.append(
            f"{counts['name']}: {counts['convolution']} convolution ops "
            "(patch extraction must use slices, not conv)"
        )
    return problems


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()

    header = f"{'artifact':<24} {'while':>5} {'dus':>4} {'dyn-slice':>9} {'dot':>4} {'conv':>4} {'pow':>4} {'KiB':>5}"
    print(header)
    print("-" * len(header))
    problems: list[str] = []
    for fname in sorted(os.listdir(args.out_dir)):
        if not fname.endswith(".hlo.txt"):
            continue
        with open(os.path.join(args.out_dir, fname)) as f:
            text = f.read()
        c = audit_text(fname.removesuffix(".hlo.txt"), text)
        problems += check(c)
        print(
            f"{c['name']:<24} {c['while']:>5} {c['dynamic-update-slice']:>4} "
            f"{c['dynamic-slice']:>9} {c['dot']:>4} {c['convolution']:>4} "
            f"{c['power']:>4} {c['bytes'] // 1024:>5}"
        )
    if problems:
        print("\nINVARIANT VIOLATIONS:", file=sys.stderr)
        for p in problems:
            print(f"  {p}", file=sys.stderr)
        sys.exit(1)
    print("\nall artifacts pass the L2 audit")


if __name__ == "__main__":
    main()
