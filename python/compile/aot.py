"""AOT lowering: JAX/Pallas task graphs → HLO text artifacts + manifest.

This is the only place Python touches the system.  ``make artifacts`` runs
it once; the Rust coordinator (rust/src/runtime) then loads
``artifacts/*.hlo.txt`` through the PJRT C API and Python never appears on
the request path again.

Interchange format is HLO **text**, not a serialized ``HloModuleProto``:
jax ≥ 0.5 emits protos with 64-bit instruction ids which the pinned
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

IMPORTANT — weights are runtime *arguments*, never baked constants: the
HLO text printer elides large literals (``constant({...})``), so a baked
weight tensor silently round-trips as zeros.  Every input (activations
and weights) is instead synthesized deterministically on both sides from
the same low-discrepancy fill (`golden_input`, mirrored bit-for-bit by
rust/src/runtime/inputs.rs), and the manifest records a golden output
checksum for end-to-end verification.

One artifact is emitted per Table 1 task *variant*.  Variants of the same
task share weight seeds and differ in their batch axis — the functional
analogue of the paper's unroll factor; the *timing* difference between
variants lives in the Rust task library.
"""

from __future__ import annotations

import argparse
import json
import os
from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model

MANIFEST_VERSION = 3

# ---------------------------------------------------------------------------
# HLO text emission (the aot_recipe.md / xla-example bridge)
# ---------------------------------------------------------------------------


def to_hlo_text(lowered) -> str:
    """jax lowering → XlaComputation → HLO text (ids reassigned by parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def assert_no_elided_constants(text: str, name: str) -> None:
    """Guard against the large-constant elision failure mode."""
    if "constant({...})" in text or "constant({ ... })" in text:
        raise RuntimeError(
            f"artifact {name}: HLO text contains an elided large constant; "
            "pass the tensor as an argument instead of baking it"
        )


# ---------------------------------------------------------------------------
# Deterministic input synthesis (mirrored by rust/src/runtime/inputs.rs)
# ---------------------------------------------------------------------------

_PHI = 0.6180339887498949  # 1/golden-ratio; low-discrepancy fill
_SALT_STRIDE = 1_000_003   # distinct streams per argument index


def golden_input(
    shape: tuple[int, ...], *, lo: float = -1.0, hi: float = 1.0, salt: int = 0
) -> np.ndarray:
    """Low-discrepancy deterministic fill, bit-identical in Rust.

    value(i) = lo + (hi-lo) * frac((salt*1_000_003 + i + 1) * PHI),
    computed in f64 and cast to f32.
    """
    n = int(np.prod(shape)) if shape else 1
    idx = np.arange(1, n + 1, dtype=np.float64) + float(salt * _SALT_STRIDE)
    frac = np.modf(idx * _PHI)[0]
    vals = lo + (hi - lo) * frac
    return vals.astype(np.float32).reshape(shape)


def checksum(arr: np.ndarray) -> dict:
    """Summary stats for golden verification (tolerant compare in Rust)."""
    flat = np.asarray(arr, dtype=np.float64).ravel()
    return {
        "sum": float(flat.sum()),
        "abs_sum": float(np.abs(flat).sum()),
        "head": [float(v) for v in flat[:8]],
    }


# ---------------------------------------------------------------------------
# Artifact registry
# ---------------------------------------------------------------------------


@dataclass
class TensorIn:
    """One runtime input: shape + deterministic fill range."""

    shape: tuple[int, ...]
    lo: float = -1.0
    hi: float = 1.0
    role: str = "activation"  # or "weight" — documentation only


def weight_in(shape: tuple[int, ...], fan_in: int) -> TensorIn:
    """He-scaled uniform fill for a weight tensor."""
    s = float(np.sqrt(2.0 / max(fan_in, 1)))
    return TensorIn(shape, lo=-s, hi=s, role="weight")


@dataclass
class Artifact:
    """One AOT-lowered task variant."""

    name: str            # e.g. "resnet_conv2_b"
    task: str            # Table-1 task id, e.g. "resnet18.conv2_x"
    variant: str         # "a" | "b" | "c"
    fn: Callable         # positional args match `inputs`
    inputs: list[TensorIn]
    tags: tuple[str, ...] = ()


def _resnet_artifacts(size: str) -> list[Artifact]:
    """ResNet-18 conv2_x..conv5_x stages, variants a (batch 1) / b (batch 4).

    Spatial dims and channel counts are scaled down from the paper's
    224×224 deployment so the CPU-PJRT functional path stays fast; the
    stage structure (two basic blocks, downsampling at stage entry for
    conv3..5) is faithful.
    """
    spec = {
        # stage: (cin, cout, hw_in, downsample)
        "small": {
            "conv2": (16, 16, 16, False),
            "conv3": (16, 32, 16, True),
            "conv4": (32, 64, 8, True),
            "conv5": (64, 128, 4, True),
        },
        "tiny": {
            "conv2": (8, 8, 8, False),
            "conv3": (8, 16, 8, True),
            "conv4": (16, 32, 4, True),
            "conv5": (32, 64, 4, True),
        },
    }[size]
    arts = []
    for stage, (cin, cout, hw, down) in spec.items():
        # weight argument order is fixed: b1w1, b1w2, [b1proj], b2w1, b2w2
        w_ins = [
            weight_in((3, 3, cin, cout), 9 * cin),
            weight_in((3, 3, cout, cout), 9 * cout),
        ]
        if down:
            w_ins.append(weight_in((1, 1, cin, cout), cin))
        w_ins += [
            weight_in((3, 3, cout, cout), 9 * cout),
            weight_in((3, 3, cout, cout), 9 * cout),
        ]

        def make(down=down):
            def fn(x, *ws):
                if down:
                    params = {
                        "b1w1": ws[0], "b1w2": ws[1], "b1proj": ws[2],
                        "b2w1": ws[3], "b2w2": ws[4],
                    }
                else:
                    params = {"b1w1": ws[0], "b1w2": ws[1], "b2w1": ws[2], "b2w2": ws[3]}
                return model.resnet_stage(x, params, downsample=down)

            return fn

        for variant, batch in (("a", 1), ("b", 4)):
            arts.append(
                Artifact(
                    name=f"resnet_{stage}_{variant}",
                    task=f"resnet18.{stage}_x",
                    variant=variant,
                    fn=make(),
                    inputs=[TensorIn((batch, hw, hw, cin))] + list(w_ins),
                    tags=("ml", "resnet18"),
                )
            )
    return arts


def _mobilenet_artifacts(size: str) -> list[Artifact]:
    """MobileNet conv_dw_pw stages 2/3/4, variants a / b (Table 1)."""
    spec = {
        "small": {
            "dw_pw_2": (16, 32, 16),
            "dw_pw_3": (32, 64, 8),
            "dw_pw_4": (64, 128, 4),
        },
        "tiny": {
            "dw_pw_2": (8, 16, 8),
            "dw_pw_3": (16, 32, 4),
            "dw_pw_4": (32, 64, 4),
        },
    }[size]
    arts = []
    for stage, (cin, cout, hw) in spec.items():

        def fn(x, wdw, wpw):
            return model.batched(lambda xi: model.mobilenet_dw_pw(xi, wdw, wpw))(x)

        w_ins = [weight_in((3, 3, cin), 9), weight_in((cin, cout), cin)]
        for variant, batch in (("a", 1), ("b", 2)):
            arts.append(
                Artifact(
                    name=f"mobilenet_{stage}_{variant}",
                    task=f"mobilenet.conv_{stage}_x",
                    variant=variant,
                    fn=fn,
                    inputs=[TensorIn((batch, hw, hw, cin))] + list(w_ins),
                    tags=("ml", "mobilenet"),
                )
            )
    return arts


def _camera_artifacts(size: str) -> list[Artifact]:
    hw = {"small": 64, "tiny": 32}[size]
    fn = model.batched(model.camera_pipeline)
    arts = []
    for variant, batch in (("a", 1), ("b", 4)):
        arts.append(
            Artifact(
                name=f"camera_pipeline_{variant}",
                task="camera.pipeline",
                variant=variant,
                fn=fn,
                inputs=[TensorIn((batch, hw, hw), lo=0.0, hi=1.0)],
                tags=("image", "camera"),
            )
        )
    return arts


def _harris_artifacts(size: str) -> list[Artifact]:
    hw = {"small": 64, "tiny": 32}[size]
    fn = model.batched(model.harris_detect)
    arts = []
    for variant, batch in (("a", 1), ("b", 2), ("c", 4)):
        arts.append(
            Artifact(
                name=f"harris_{variant}",
                task="harris.corner",
                variant=variant,
                fn=fn,
                inputs=[TensorIn((batch, hw, hw), lo=0.0, hi=1.0)],
                tags=("image", "harris"),
            )
        )
    return arts


def _micro_artifacts(size: str) -> list[Artifact]:
    """Plain Pallas-matmul artifact for runtime microbenchmarks."""
    n = {"small": 128, "tiny": 32}[size]

    def fn(x, w):
        from .kernels import matmul_mac

        return matmul_mac(x, w)

    return [
        Artifact(
            name=f"matmul_{n}",
            task="micro.matmul",
            variant="a",
            fn=fn,
            inputs=[TensorIn((n, n)), TensorIn((n, n), role="weight")],
            tags=("micro",),
        )
    ]


def build_registry(size: str) -> list[Artifact]:
    return (
        _resnet_artifacts(size)
        + _mobilenet_artifacts(size)
        + _camera_artifacts(size)
        + _harris_artifacts(size)
        + _micro_artifacts(size)
    )


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------


def golden_args(art: Artifact) -> list[np.ndarray]:
    """Deterministic argument set; arg k uses salt k."""
    return [
        golden_input(t.shape, lo=t.lo, hi=t.hi, salt=k)
        for k, t in enumerate(art.inputs)
    ]


def lower_artifact(art: Artifact, out_dir: str) -> dict:
    """Lower one artifact; returns its manifest entry."""
    specs = [jax.ShapeDtypeStruct(t.shape, jnp.float32) for t in art.inputs]
    lowered = jax.jit(art.fn).lower(*specs)
    text = to_hlo_text(lowered)
    assert_no_elided_constants(text, art.name)
    fname = f"{art.name}.hlo.txt"
    with open(os.path.join(out_dir, fname), "w") as f:
        f.write(text)

    # Golden run on the deterministic inputs for end-to-end verification.
    args = golden_args(art)
    y = np.asarray(jax.jit(art.fn)(*args))

    return {
        "name": art.name,
        "file": fname,
        "task": art.task,
        "variant": art.variant,
        "tags": list(art.tags),
        "inputs": [
            {
                "shape": list(t.shape),
                "dtype": "f32",
                "range": [t.lo, t.hi],
                "salt": k,
                "role": t.role,
            }
            for k, t in enumerate(art.inputs)
        ],
        "output": {"shape": list(y.shape), "dtype": "f32"},
        "golden": checksum(y),
        "hlo_bytes": len(text),
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--size", choices=("small", "tiny"), default="small")
    ap.add_argument("--only", default=None, help="substring filter on artifact names")
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    registry = build_registry(args.size)
    if args.only:
        registry = [a for a in registry if args.only in a.name]

    entries = []
    for art in registry:
        entry = lower_artifact(art, args.out_dir)
        entries.append(entry)
        print(
            f"  {art.name:<24} in={entry['inputs'][0]['shape']} "
            f"out={entry['output']['shape']} hlo={entry['hlo_bytes']//1024}KiB "
            f"args={len(entry['inputs'])}"
        )

    manifest = {
        "version": MANIFEST_VERSION,
        "size": args.size,
        "artifacts": entries,
    }
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {len(entries)} artifacts + manifest.json to {args.out_dir}")


if __name__ == "__main__":
    main()
