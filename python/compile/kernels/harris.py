"""Harris corner-response Pallas kernel.

Implements the Harris task of Table 1: Sobel gradients, 3x3 box-windowed
structure tensor, and the corner response R = det(M) − k·trace(M)².  On
the CGRA this is a deep stencil pipeline across PE tiles with MEM-tile
line buffers; here it is a VPU stencil over a VMEM-resident row band with
a 2-pixel halo (1 for Sobel + 1 for the window sum).

Grid = row bands (the unrollable axis: the paper's Harris variants a/b/c
scale 2→4→7 array-slices for 1→2→4 pixels/cycle).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: Harris sensitivity constant (standard value, also used by ref.py).
HARRIS_K = 0.04


def _harris_kernel(img_ref, o_ref, *, block_h: int, k: float):
    """img_ref: full (HP+4, W+4) padded plane; o_ref: (block_h, W) band."""
    bh = o_ref.shape[0]
    w = o_ref.shape[1]
    row0 = pl.program_id(0) * block_h
    x = pl.load(img_ref, (pl.dslice(row0, bh + 4), slice(None))).astype(jnp.float32)

    def sh(a, di, dj, h_, w_):
        return jax.lax.dynamic_slice(a, (di, dj), (h_, w_))

    # Sobel gradients on the interior (bh+2, w+2) region.
    gh, gw = bh + 2, w + 2

    def grad(weights):
        # 3x3 correlation, skipping zero taps
        acc = jnp.zeros((gh, gw), jnp.float32)
        for di in range(3):
            for dj in range(3):
                wgt = weights[di][dj]
                if wgt != 0.0:
                    acc += wgt * sh(x, di, dj, gh, gw)
        return acc

    sobel_x = ((-1.0, 0.0, 1.0), (-2.0, 0.0, 2.0), (-1.0, 0.0, 1.0))
    sobel_y = ((-1.0, -2.0, -1.0), (0.0, 0.0, 0.0), (1.0, 2.0, 1.0))
    ix = grad(sobel_x)
    iy = grad(sobel_y)

    ixx, iyy, ixy = ix * ix, iy * iy, ix * iy

    def window(a):
        # 3x3 box sum over the (bh, w) interior of a (bh+2, w+2) plane
        acc = jnp.zeros((bh, w), jnp.float32)
        for di in range(3):
            for dj in range(3):
                acc += sh(a, di, dj, bh, w)
        return acc

    sxx, syy, sxy = window(ixx), window(iyy), window(ixy)
    det = sxx * syy - sxy * sxy
    tr = sxx + syy
    o_ref[...] = det - k * tr * tr


@functools.partial(jax.jit, static_argnames=("block_h", "interpret"))
def harris_response(
    img: jax.Array,
    *,
    k: float = HARRIS_K,
    block_h: int | None = None,
    interpret: bool = True,
) -> jax.Array:
    """Harris corner response of a grayscale (H, W) image, float32 (H, W).

    Border handling: reflect padding (2 px: Sobel + window halos).
    """
    if img.ndim != 2:
        raise ValueError(f"harris_response expects (H, W) grayscale, got {img.shape}")
    h, w = img.shape
    if block_h is None:
        # single-band fast path (see demosaic; EXPERIMENTS.md §Perf)
        block_h = h if h * w * 6 <= 4_000_000 else 32

    hp = (h + block_h - 1) // block_h * block_h
    xp = jnp.pad(img, ((2, 2 + hp - h), (2, 2)), mode="reflect")

    grid = (hp // block_h,)
    out = pl.pallas_call(
        functools.partial(_harris_kernel, block_h=block_h, k=float(k)),
        grid=grid,
        in_specs=[pl.BlockSpec(xp.shape, lambda i: (0, 0))],
        out_specs=pl.BlockSpec((block_h, w), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((hp, w), jnp.float32),
        interpret=interpret,
    )(xp)
    return out[:h]
