"""Bayer RGGB bilinear demosaic Pallas kernel (camera-pipeline front end).

The camera-pipeline task in Table 1 ingests RAW sensor data in RGGB Bayer
layout and produces RGB.  On the CGRA this is a line-buffered stencil over
MEM tiles; here the kernel reconstructs the three colour planes with
phase-aware bilinear averages over a row band held in VMEM.

The grid iterates over row bands — the unrollable axis (more array-slices
⇒ more bands in flight), matching how the compiler unrolls the camera
pipeline from 4 to 6 slices in the paper's variably-sized-region example.
Bands overlap by a 1-pixel halo, so the kernel dynamically slices its band
out of the full padded plane (the Pallas idiom for overlapping stencil
blocks).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _demosaic_kernel(raw_ref, o_ref, *, block_h: int):
    """raw_ref: full (HP+2, W+2) padded plane; o_ref: (block_h, W, 3) band.

    Phase layout (RGGB, even rows R G, odd rows G B):
      (0,0)=R  (0,1)=G  (1,0)=G  (1,1)=B
    Bilinear reconstruction via the standard shifted-average masks.
    ``block_h`` is even, so every band starts on an even Bayer row and the
    in-band parity masks are band-invariant.
    """
    bh = o_ref.shape[0]
    w = o_ref.shape[1]
    row0 = pl.program_id(0) * block_h
    x = pl.load(raw_ref, (pl.dslice(row0, bh + 2), slice(None))).astype(jnp.float32)

    def sh(di, dj):
        # neighbour plane at offset (di, dj) for the interior (1..bh, 1..w)
        return jax.lax.dynamic_slice(x, (1 + di, 1 + dj), (bh, w))

    c = sh(0, 0)
    horiz = (sh(0, -1) + sh(0, 1)) * 0.5
    vert = (sh(-1, 0) + sh(1, 0)) * 0.5
    cross = (sh(0, -1) + sh(0, 1) + sh(-1, 0) + sh(1, 0)) * 0.25
    diag = (sh(-1, -1) + sh(-1, 1) + sh(1, -1) + sh(1, 1)) * 0.25

    row_idx = jax.lax.broadcasted_iota(jnp.int32, (bh, w), 0)
    col_idx = jax.lax.broadcasted_iota(jnp.int32, (bh, w), 1)
    even_r = (row_idx % 2) == 0
    even_c = (col_idx % 2) == 0

    at_r = even_r & even_c        # red site
    at_gr = even_r & ~even_c      # green on red row
    at_gb = ~even_r & even_c      # green on blue row
    at_b = ~even_r & ~even_c      # blue site

    r = jnp.where(at_r, c, jnp.where(at_gr, horiz, jnp.where(at_gb, vert, diag)))
    g = jnp.where(at_r | at_b, cross, c)
    b = jnp.where(at_b, c, jnp.where(at_gb, horiz, jnp.where(at_gr, vert, diag)))

    o_ref[...] = jnp.stack([r, g, b], axis=-1)


@functools.partial(jax.jit, static_argnames=("block_h", "interpret"))
def demosaic_rggb(
    raw: jax.Array,
    *,
    block_h: int | None = None,
    interpret: bool = True,
) -> jax.Array:
    """Bilinear-demosaic an (H, W) RGGB RAW plane to (H, W, 3) float32.

    H and W must be even (whole Bayer quads); rows are processed in
    ``block_h``-row bands with a 1-pixel reflect-padded halo.  ``block_h``
    must be even so every band starts on the same Bayer phase.
    """
    if raw.ndim != 2:
        raise ValueError(f"demosaic_rggb expects (H, W) RAW, got {raw.shape}")
    h, w = raw.shape
    if block_h is None:
        # single-band fast path when the plane fits a VMEM-sized budget
        # (EXPERIMENTS.md §Perf: the interpret-mode grid loop is costly
        # under the pinned XLA); otherwise 32-row bands.
        hp2 = (h + 1) // 2 * 2
        block_h = hp2 if hp2 * w * 3 <= 4_000_000 else 32
    if block_h % 2 != 0:
        raise ValueError(f"block_h must be even, got {block_h}")
    if h % 2 or w % 2:
        raise ValueError(f"RAW dims must be even (Bayer quads), got {raw.shape}")

    hp = (h + block_h - 1) // block_h * block_h
    # reflect-pad: 1-px halo + bottom fill to a whole number of bands
    xp = jnp.pad(raw, ((1, 1 + hp - h), (1, 1)), mode="reflect")

    grid = (hp // block_h,)
    out = pl.pallas_call(
        functools.partial(_demosaic_kernel, block_h=block_h),
        grid=grid,
        in_specs=[
            # every band sees the whole padded plane and slices its halo
            # window dynamically (overlapping-stencil idiom)
            pl.BlockSpec(xp.shape, lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_h, w, 3), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((hp, w, 3), jnp.float32),
        interpret=interpret,
    )(xp)
    return out[:h]
