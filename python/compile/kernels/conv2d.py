"""2-D convolution lowered to im2col × Pallas matmul.

On the paper's CGRA a conv layer is mapped spatially: PE tiles form a MAC
network fed by MEM-tile line buffers.  On a TPU-shaped machine the same
arithmetic is expressed as an im2col patch-matrix multiplied on the MXU —
the ``MACs/cycle`` column of Table 1 corresponds to the matmul tile
throughput here (DESIGN.md §Hardware-Adaptation).

The patch extraction is plain lax (it lowers to cheap reshapes/slices and
fuses in XLA); the arithmetically dominant matmul runs in the Pallas MAC
kernel from :mod:`matmul`.
"""

import functools

import jax
import jax.lax as lax
import jax.numpy as jnp

from .matmul import matmul_mac


def _im2col(x: jax.Array, kh: int, kw: int, stride: int, padding: int) -> jax.Array:
    """NHWC image → (N*OH*OW, KH*KW*C) patch matrix.

    Built from `kh*kw` strided slices concatenated on the channel axis
    (KH,KW,C feature order, matching a flattened HWIO weight).  Perf note
    (EXPERIMENTS.md §Perf): `lax.conv_general_dilated_patches` lowers to
    a real convolution, which the pinned XLA 0.5.1 CPU backend executes
    ~10x slower than these pure slice/concat ops.
    """
    n, h, w, c = x.shape
    xp = jnp.pad(x, ((0, 0), (padding, padding), (padding, padding), (0, 0)))
    hp, wp = h + 2 * padding, w + 2 * padding
    oh = (hp - kh) // stride + 1
    ow = (wp - kw) // stride + 1
    taps = []
    for di in range(kh):
        for dj in range(kw):
            taps.append(
                lax.slice(
                    xp,
                    (0, di, dj, 0),
                    (n, di + (oh - 1) * stride + 1, dj + (ow - 1) * stride + 1, c),
                    (1, stride, stride, 1),
                )
            )
    patches = jnp.concatenate(taps, axis=-1)  # (n, oh, ow, kh*kw*c)
    return patches.reshape(n * oh * ow, kh * kw * c), (n, oh, ow)


@functools.partial(
    jax.jit,
    static_argnames=("stride", "padding", "block_m", "block_n", "block_k", "interpret"),
)
def conv2d_im2col(
    x: jax.Array,
    w: jax.Array,
    *,
    stride: int = 1,
    padding: int = 1,
    block_m: int | None = None,
    block_n: int | None = None,
    block_k: int | None = None,
    interpret: bool = True,
) -> jax.Array:
    """NHWC conv with HWIO weights via im2col + Pallas MAC matmul.

    ``x``: (N, H, W, C_in); ``w``: (KH, KW, C_in, C_out).
    Returns (N, OH, OW, C_out) float32.
    """
    if x.ndim != 4 or w.ndim != 4:
        raise ValueError(f"conv2d_im2col expects NHWC x HWIO, got {x.shape}, {w.shape}")
    kh, kw, cin, cout = w.shape
    if x.shape[-1] != cin:
        raise ValueError(f"channel mismatch: x {x.shape} vs w {w.shape}")

    cols, (n, oh, ow) = _im2col(x, kh, kw, stride, padding)
    wmat = w.reshape(kh * kw * cin, cout)
    out = matmul_mac(
        cols,
        wmat,
        block_m=block_m,
        block_n=block_n,
        block_k=block_k,
        interpret=interpret,
    )
    return out.reshape(n, oh, ow, cout)
