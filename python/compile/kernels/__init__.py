"""Layer-1 Pallas kernels for the CGRA-MTE benchmark tasks.

Each kernel is the compute hot-spot of one benchmark task from Table 1 of
the paper, rethought for a TPU-shaped machine (see DESIGN.md
S Hardware-Adaptation): the CGRA's PE-tile MAC fabric maps onto MXU matmul
tiles, MEM-tile scratchpads onto VMEM blocks, and the GLB bank streaming
schedule onto ``BlockSpec`` index maps.

All kernels are lowered with ``interpret=True`` -- the CPU PJRT plugin used
by the Rust runtime cannot execute Mosaic custom-calls.  Correctness is
asserted against the pure-jnp oracles in :mod:`ref` by the pytest suite.
"""

from .matmul import matmul_mac
from .conv2d import conv2d_im2col
from .depthwise import depthwise_conv
from .demosaic import demosaic_rggb
from .harris import harris_response

__all__ = [
    "matmul_mac",
    "conv2d_im2col",
    "depthwise_conv",
    "demosaic_rggb",
    "harris_response",
]
