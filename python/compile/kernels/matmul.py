"""Tiled matmul-MAC Pallas kernel.

This is the workhorse of the ML-domain tasks (ResNet-18 / MobileNet conv
stages are lowered to im2col matmuls, see :mod:`conv2d`).  The block
decomposition deliberately mirrors the paper's hardware abstraction:

* one grid step along ``m`` plays the role of one *array-slice* worth of
  PE-tile MACs (the scheduler's unroll factor widens this axis),
* the ``(block_m, block_k)`` / ``(block_k, block_n)`` operand blocks are
  the VMEM-resident working set, standing in for MEM-tile scratchpads,
* the ``k`` grid axis is the GLB→array streaming schedule: operand blocks
  stream in while partial sums accumulate in the output block.

The kernel accumulates in ``float32`` regardless of input dtype, matching
the PE tile's widened MAC accumulator.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(x_ref, w_ref, o_ref, *, k_steps: int):
    """Accumulating matmul tile: o[m,n] += x[m,k] @ w[k,n]."""

    @pl.when(pl.program_id(2) == 0)
    def _zero_acc():
        o_ref[...] = jnp.zeros_like(o_ref)

    # MXU-shaped MAC: always accumulate in f32 (the PE accumulator width).
    o_ref[...] += jnp.dot(
        x_ref[...].astype(jnp.float32),
        w_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )


def _ceil_to(value: int, mult: int) -> int:
    return (value + mult - 1) // mult * mult


def _auto_block(dim: int, cap: int) -> int:
    """Shape-adaptive block size: whole (8-padded) dim up to `cap`.

    Perf note (EXPERIMENTS.md §Perf): interpret-mode Pallas executes the
    grid as an XLA while-loop of dynamic-slice + dot steps, so per-step
    overhead dominates small blocks.  Sweeping the Table-1 conv shapes
    showed 5–13x speedups moving from fixed 32³ blocks to blocks that
    cover the (padded) dimension up to {M,N}≤128 / K≤512 — on a real TPU
    the same shapes stay comfortably inside VMEM (≤ ~80 KiB per operand
    block) and multiples of the 128-lane MXU tile.
    """
    return min(cap, _ceil_to(dim, 8))


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_n", "block_k", "interpret")
)
def matmul_mac(
    x: jax.Array,
    w: jax.Array,
    *,
    block_m: int | None = None,
    block_n: int | None = None,
    block_k: int | None = None,
    interpret: bool = True,
) -> jax.Array:
    """Compute ``x @ w`` with a tiled Pallas MAC kernel.

    Inputs of any ``(M, K) x (K, N)`` shape are zero-padded up to block
    multiples; the result is sliced back to ``(M, N)``.  Output dtype is
    float32 (the accumulator dtype).  Block sizes default to a
    shape-adaptive choice (see `_auto_block`); pass them explicitly to
    pin a tiling.
    """
    if x.ndim != 2 or w.ndim != 2:
        raise ValueError(f"matmul_mac expects 2-D operands, got {x.shape} @ {w.shape}")
    m, k = x.shape
    k2, n = w.shape
    if k != k2:
        raise ValueError(f"contraction mismatch: {x.shape} @ {w.shape}")

    # Single-block fast path: when every operand block fits a VMEM-sized
    # budget (16 MB f32 ≈ 4M elements), run the whole matmul as one grid
    # step.  Perf iteration 2 (EXPERIMENTS.md §Perf): the interpret-mode
    # grid lowers to an XLA while-loop whose carried buffers the pinned
    # XLA 0.5.1 CPU backend copies every iteration — grid=1 removes the
    # loop entirely (conv-shaped matmuls: 0.97 → 0.55 ms under old XLA).
    mp8, kp8, np8 = _ceil_to(m, 8), _ceil_to(k, 8), _ceil_to(n, 8)
    if block_m is None and block_n is None and block_k is None:
        total = mp8 * kp8 + kp8 * np8 + mp8 * np8
        if total <= 4_000_000:
            block_m, block_k, block_n = mp8, kp8, np8
    block_m = block_m or _auto_block(m, 128)
    block_n = block_n or _auto_block(n, 128)
    block_k = block_k or _auto_block(k, 512)

    mp, kp, np_ = _ceil_to(m, block_m), _ceil_to(k, block_k), _ceil_to(n, block_n)
    xp = jnp.pad(x, ((0, mp - m), (0, kp - k)))
    wp = jnp.pad(w, ((0, kp - k), (0, np_ - n)))

    grid = (mp // block_m, np_ // block_n, kp // block_k)
    out = pl.pallas_call(
        functools.partial(_matmul_kernel, k_steps=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=interpret,
    )(xp, wp)
    return out[:m, :n]
