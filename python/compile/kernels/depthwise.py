"""Depthwise 3x3 convolution Pallas kernel (MobileNet ``conv_dw`` half).

A depthwise conv has no channel contraction, so it does not map onto the
MXU; on the CGRA it occupies PE tiles doing independent per-channel MACs.
On a TPU-shaped machine it is a VPU (vector) stencil: the kernel holds a
``(H+2, W+2, block_c)`` halo block in VMEM and accumulates the nine
shifted element-wise products.  The grid iterates over channel blocks —
the axis the scheduler's unroll factor widens (more array-slices ⇒ more
channel blocks in flight).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _dw_kernel(x_ref, w_ref, o_ref, *, kh: int, kw: int):
    """x_ref: (H+kh-1, W+kw-1, C_blk) halo block; w_ref: (kh, kw, C_blk)."""
    oh = o_ref.shape[0]
    ow = o_ref.shape[1]
    acc = jnp.zeros(o_ref.shape, jnp.float32)
    for di in range(kh):
        for dj in range(kw):
            window = x_ref[di : di + oh, dj : dj + ow, :].astype(jnp.float32)
            acc += window * w_ref[di, dj, :].astype(jnp.float32)
    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("block_c", "interpret"))
def depthwise_conv(
    x: jax.Array,
    w: jax.Array,
    *,
    block_c: int | None = None,
    interpret: bool = True,
) -> jax.Array:
    """Depthwise conv, stride 1, SAME padding.

    ``x``: (H, W, C); ``w``: (KH, KW, C).  Returns (H, W, C) float32.
    """
    if x.ndim != 3 or w.ndim != 3:
        raise ValueError(f"depthwise_conv expects (H,W,C) x (KH,KW,C), got {x.shape}, {w.shape}")
    h, wd, c = x.shape
    kh, kw, cw = w.shape
    if c != cw:
        raise ValueError(f"channel mismatch: {x.shape} vs {w.shape}")
    ph, pw = kh // 2, kw // 2

    if block_c is None:
        # single-block fast path when the halo block fits a VMEM-sized
        # budget (see matmul._auto_block; EXPERIMENTS.md §Perf) — the
        # interpret-mode grid loop is expensive under the pinned XLA.
        cp8 = (c + 7) // 8 * 8
        block_c = cp8 if (h + kh - 1) * (wd + kw - 1) * cp8 <= 4_000_000 else 16

    cp = (c + block_c - 1) // block_c * block_c
    xp = jnp.pad(x, ((ph, kh - 1 - ph), (pw, kw - 1 - pw), (0, cp - c)))
    wp = jnp.pad(w, ((0, 0), (0, 0), (0, cp - c)))

    grid = (cp // block_c,)
    out = pl.pallas_call(
        functools.partial(_dw_kernel, kh=kh, kw=kw),
        grid=grid,
        in_specs=[
            pl.BlockSpec((h + kh - 1, wd + kw - 1, block_c), lambda ci: (0, 0, ci)),
            pl.BlockSpec((kh, kw, block_c), lambda ci: (0, 0, ci)),
        ],
        out_specs=pl.BlockSpec((h, wd, block_c), lambda ci: (0, 0, ci)),
        out_shape=jax.ShapeDtypeStruct((h, wd, cp), jnp.float32),
        interpret=interpret,
    )(xp, wp)
    return out[:, :, :c]
