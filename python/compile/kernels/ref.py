"""Pure-jnp oracles for every Layer-1 Pallas kernel.

These are the correctness contracts: the pytest suite asserts
``assert_allclose(kernel(...), ref(...))`` over hypothesis-driven shape and
dtype sweeps.  Keep these boring and obviously correct — no Pallas, no
tiling, just textbook jnp.
"""

import jax
import jax.lax as lax
import jax.numpy as jnp

HARRIS_K = 0.04


def matmul_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    """f32-accumulated matmul."""
    return jnp.dot(
        x.astype(jnp.float32), w.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )


def conv2d_ref(x: jax.Array, w: jax.Array, *, stride: int = 1, padding: int = 1) -> jax.Array:
    """NHWC x HWIO conv, f32 accumulation."""
    return lax.conv_general_dilated(
        x.astype(jnp.float32),
        w.astype(jnp.float32),
        window_strides=(stride, stride),
        padding=((padding, padding), (padding, padding)),
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def depthwise_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    """(H,W,C) x (KH,KW,C) depthwise conv, stride 1, SAME padding."""
    h, wd, c = x.shape
    kh, kw, _ = w.shape
    out = lax.conv_general_dilated(
        x[None].astype(jnp.float32),
        w[:, :, None, :].astype(jnp.float32),  # HWIO with I=1, one group/channel
        window_strides=(1, 1),
        padding=((kh // 2, kh - 1 - kh // 2), (kw // 2, kw - 1 - kw // 2)),
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=c,
    )
    return out[0]


def demosaic_ref(raw: jax.Array) -> jax.Array:
    """Bilinear RGGB demosaic, reflect borders. (H,W) -> (H,W,3) f32."""
    h, w = raw.shape
    x = jnp.pad(raw, 1, mode="reflect").astype(jnp.float32)

    def sh(di, dj):
        return x[1 + di : 1 + di + h, 1 + dj : 1 + dj + w]

    c = sh(0, 0)
    horiz = (sh(0, -1) + sh(0, 1)) * 0.5
    vert = (sh(-1, 0) + sh(1, 0)) * 0.5
    cross = (sh(0, -1) + sh(0, 1) + sh(-1, 0) + sh(1, 0)) * 0.25
    diag = (sh(-1, -1) + sh(-1, 1) + sh(1, -1) + sh(1, 1)) * 0.25

    rows = jnp.arange(h)[:, None]
    cols = jnp.arange(w)[None, :]
    even_r = (rows % 2) == 0
    even_c = (cols % 2) == 0
    at_r = even_r & even_c
    at_gr = even_r & ~even_c
    at_gb = ~even_r & even_c
    at_b = ~even_r & ~even_c

    r = jnp.where(at_r, c, jnp.where(at_gr, horiz, jnp.where(at_gb, vert, diag)))
    g = jnp.where(at_r | at_b, cross, c)
    b = jnp.where(at_b, c, jnp.where(at_gb, horiz, jnp.where(at_gr, vert, diag)))
    return jnp.stack([r, g, b], axis=-1)


def harris_ref(img: jax.Array, *, k: float = HARRIS_K) -> jax.Array:
    """Harris response: Sobel grads, 3x3 box window, det - k*tr^2."""
    h, w = img.shape
    x = jnp.pad(img, 2, mode="reflect").astype(jnp.float32)

    def corr3(a, weights, oh, ow):
        acc = jnp.zeros((oh, ow), jnp.float32)
        for di in range(3):
            for dj in range(3):
                wgt = weights[di][dj]
                if wgt != 0.0:
                    acc = acc + wgt * a[di : di + oh, dj : dj + ow]
        return acc

    sobel_x = ((-1.0, 0.0, 1.0), (-2.0, 0.0, 2.0), (-1.0, 0.0, 1.0))
    sobel_y = ((-1.0, -2.0, -1.0), (0.0, 0.0, 0.0), (1.0, 2.0, 1.0))
    box = ((1.0, 1.0, 1.0), (1.0, 1.0, 1.0), (1.0, 1.0, 1.0))

    ix = corr3(x, sobel_x, h + 2, w + 2)
    iy = corr3(x, sobel_y, h + 2, w + 2)
    sxx = corr3(ix * ix, box, h, w)
    syy = corr3(iy * iy, box, h, w)
    sxy = corr3(ix * iy, box, h, w)
    det = sxx * syy - sxy * sxy
    tr = sxx + syy
    return det - k * tr * tr
