# Build-time (Layer 1/2) artifact pipeline + tier-1 shortcuts.
#
# `make artifacts` AOT-lowers every Table 1 task variant from JAX/Pallas
# to HLO text plus a golden-checksum manifest (requires jax; see
# python/compile/aot.py).  The Rust coordinator loads the result at
# rust/artifacts/ when built with `--features xla`; without that feature
# the deterministic stub executor serves a built-in synthetic manifest
# and no artifacts are needed.

.PHONY: build test artifacts doc bench-smoke bench-noc bench-simperf bench-serve bench-obs

build:
	cargo build --release

test:
	cargo test -q

artifacts:
	cd python/compile && python3 aot.py --out-dir ../../rust/artifacts --size small

doc:
	cargo doc --no-deps

# Every ablation's CI liveness mode in one command: cheap end-to-end
# passes that also refresh the BENCH_*.json perf-trajectory files
# (migration, shard scaling, energy cap + EDP).  Acceptance bars inside
# each bench are enforced — a non-zero exit here is a regression.
bench-smoke:
	cargo bench --bench ablation_migration -- --smoke
	cargo bench --bench ablation_shards -- --smoke
	cargo bench --bench ablation_energy -- --smoke
	cargo bench --bench ablation_qos -- --smoke
	cargo bench --bench ablation_noc -- --smoke
	cargo bench --bench simperf -- --smoke
	cargo bench --bench serve_saturation -- --smoke
	cargo bench --bench obs_overhead -- --smoke

# NoC ablation at full duration: comm-aware vs oblivious placement on
# the streaming-pipeline preset plus the churn guard arm; writes
# BENCH_noc.json and enforces the comm-aware-wins acceptance bars.
bench-noc:
	cargo bench --bench ablation_noc

# Simulator hot-path throughput (events/sec) with the >10% perf-
# regression gate against rust/benches/simperf_baseline.json; writes
# BENCH_simperf.json.  Full (non-smoke) mode for trustworthy numbers —
# regenerate the committed baseline with UPDATE_SIMPERF_BASELINE=1 after
# a validated perf change.
bench-simperf:
	cargo bench --bench simperf

# Serving-front saturation: a 10k-idle-connection army (clamped to the
# fd limit) plus closed-loop load against the threaded front, the
# reactor front (text), and the reactor front (binary framing); writes
# BENCH_serve.json and enforces the reactor-beats-thread-per-conn gate
# on accepted QPS and p99.  Raise `ulimit -n` for the full army.
bench-serve:
	cargo bench --bench serve_saturation

# Observability overhead: the simperf presets with [obs] off vs on,
# writing BENCH_obs.json and enforcing the ≤5% events/sec overhead gate
# for the full journal + metrics-registry instrumentation.
bench-obs:
	cargo bench --bench obs_overhead
